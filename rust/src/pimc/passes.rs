//! The optimization passes and their configuration.
//!
//! A [`PassConfig`] is a set of independent, composable lowering passes; the
//! paper's four evaluation points ([`OptLevel`]) are just four named presets
//! over this space (see the table in the [module docs](crate::pimc)). The
//! config is `Copy + Eq + Hash` because it is carried by plans and used as a
//! plan-cache key.

use anyhow::{bail, Result};

use crate::routines::OptLevel;

/// One optimization pass of the pipeline. See the [`crate::pimc`] module
/// docs for what each pass does and which paper section it reproduces.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Pass {
    /// Retire the mirrored even/odd micro-ops of a butterfly in one
    /// broadcast command slot (the Fig 6 bank-pair shared-command wiring).
    BankPairFuse,
    /// §6.1 `sw-opt`: strength-reduce ω ∈ {±1, ±j} butterflies to pim-ADD.
    TwiddleStrengthReduce,
    /// §6.2 `hw-opt`: select the dual-write MADD+SUB ALU ops.
    MaddSubFuse,
    /// Forward open-row reads into dual-write consumers, deleting dead
    /// x2-staging pim-MOVs (same-half trivial classes, cross-row regime).
    RedundantMovElim,
    /// Serpentine block order across stages: start each stage on the rows
    /// the previous one left open, saving tRP+tRAS charges.
    RowSwitchSchedule,
}

impl Pass {
    pub const ALL: [Pass; 5] = [
        Pass::BankPairFuse,
        Pass::TwiddleStrengthReduce,
        Pass::MaddSubFuse,
        Pass::RedundantMovElim,
        Pass::RowSwitchSchedule,
    ];

    /// Short name, used by `--passes` specs and ablation reports.
    pub fn name(self) -> &'static str {
        match self {
            Pass::BankPairFuse => "pairfuse",
            Pass::TwiddleStrengthReduce => "twiddle",
            Pass::MaddSubFuse => "maddsub",
            Pass::RedundantMovElim => "movelim",
            Pass::RowSwitchSchedule => "rowsched",
        }
    }
}

impl std::fmt::Display for Pass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// An enabled-pass set. [`Default`] is the empty set (every butterfly takes
/// the general Fig 14 routine and every micro-op pays its own command slot).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct PassConfig {
    pub bank_pair_fuse: bool,
    pub twiddle_strength_reduce: bool,
    pub madd_sub_fuse: bool,
    pub redundant_mov_elim: bool,
    pub row_switch_schedule: bool,
}

impl PassConfig {
    /// The empty pipeline: no strength reduction, no dual-write selection,
    /// every micro-op in its own slot.
    pub const NONE: PassConfig = PassConfig {
        bank_pair_fuse: false,
        twiddle_strength_reduce: false,
        madd_sub_fuse: false,
        redundant_mov_elim: false,
        row_switch_schedule: false,
    };

    /// The paper preset for `opt` (same mapping as `From<OptLevel>`).
    pub fn preset(opt: OptLevel) -> PassConfig {
        let base = PassConfig { bank_pair_fuse: true, ..PassConfig::NONE };
        match opt {
            OptLevel::Base => base,
            OptLevel::Sw => PassConfig { twiddle_strength_reduce: true, ..base },
            OptLevel::Hw => PassConfig { madd_sub_fuse: true, ..base },
            OptLevel::SwHw => {
                PassConfig { twiddle_strength_reduce: true, madd_sub_fuse: true, ..base }
            }
        }
    }

    pub fn enabled(self, pass: Pass) -> bool {
        match pass {
            Pass::BankPairFuse => self.bank_pair_fuse,
            Pass::TwiddleStrengthReduce => self.twiddle_strength_reduce,
            Pass::MaddSubFuse => self.madd_sub_fuse,
            Pass::RedundantMovElim => self.redundant_mov_elim,
            Pass::RowSwitchSchedule => self.row_switch_schedule,
        }
    }

    /// This config plus `pass`.
    pub fn with(mut self, pass: Pass) -> PassConfig {
        match pass {
            Pass::BankPairFuse => self.bank_pair_fuse = true,
            Pass::TwiddleStrengthReduce => self.twiddle_strength_reduce = true,
            Pass::MaddSubFuse => self.madd_sub_fuse = true,
            Pass::RedundantMovElim => self.redundant_mov_elim = true,
            Pass::RowSwitchSchedule => self.row_switch_schedule = true,
        }
        self
    }

    /// This config minus `pass`.
    pub fn without(mut self, pass: Pass) -> PassConfig {
        match pass {
            Pass::BankPairFuse => self.bank_pair_fuse = false,
            Pass::TwiddleStrengthReduce => self.twiddle_strength_reduce = false,
            Pass::MaddSubFuse => self.madd_sub_fuse = false,
            Pass::RedundantMovElim => self.redundant_mov_elim = false,
            Pass::RowSwitchSchedule => self.row_switch_schedule = false,
        }
        self
    }

    /// Enabled passes, in [`Pass::ALL`] order.
    pub fn passes(self) -> Vec<Pass> {
        Pass::ALL.into_iter().filter(|&p| self.enabled(p)).collect()
    }

    /// True when the set needs the §6.2 ALU augmentation
    /// (`PimConfig::hw_maddsub`).
    pub fn needs_hw(self) -> bool {
        self.madd_sub_fuse
    }

    /// The paper preset this config equals exactly, if any.
    pub fn opt_level(self) -> Option<OptLevel> {
        OptLevel::ALL.into_iter().find(|&opt| self == PassConfig::preset(opt))
    }

    /// Stable human name: the paper preset name where one matches (possibly
    /// with `+movelim`/`+rowsched` suffixes), else the enabled-pass list.
    pub fn name(self) -> String {
        let core = PassConfig {
            redundant_mov_elim: false,
            row_switch_schedule: false,
            ..self
        };
        if let Some(opt) = core.opt_level() {
            let mut s = opt.name().to_string();
            if self.redundant_mov_elim {
                s.push_str("+movelim");
            }
            if self.row_switch_schedule {
                s.push_str("+rowsched");
            }
            return s;
        }
        let parts: Vec<&str> = self.passes().iter().map(|p| p.name()).collect();
        if parts.is_empty() {
            "none".to_string()
        } else {
            parts.join("+")
        }
    }

    /// Parse a `--passes` spec: tokens separated by `,` or `+`, each either
    /// a preset (`base`/`sw`/`hw`/`swhw`/`all`/`none`) or a pass name
    /// ([`Pass::name`]); the union of all tokens is returned.
    pub fn parse(spec: &str) -> Result<PassConfig> {
        let mut cfg = PassConfig::NONE;
        // `union` keeps presets single-sourced in `PassConfig::preset`.
        let union = |cfg: PassConfig, other: PassConfig| {
            Pass::ALL
                .into_iter()
                .filter(|&p| other.enabled(p))
                .fold(cfg, PassConfig::with)
        };
        let sep = |c: char| c == ',' || c == '+';
        for token in spec.split(sep).map(str::trim).filter(|t| !t.is_empty()) {
            cfg = match token {
                "none" => cfg,
                "all" => Pass::ALL.into_iter().fold(cfg, PassConfig::with),
                "base" | "pim-base" => union(cfg, PassConfig::preset(OptLevel::Base)),
                "sw" | "sw-opt" => union(cfg, PassConfig::preset(OptLevel::Sw)),
                "hw" | "hw-opt" => union(cfg, PassConfig::preset(OptLevel::Hw)),
                "swhw" | "sw-hw-opt" | "pimacolaba" => {
                    union(cfg, PassConfig::preset(OptLevel::SwHw))
                }
                "pairfuse" => cfg.with(Pass::BankPairFuse),
                "twiddle" => cfg.with(Pass::TwiddleStrengthReduce),
                "maddsub" => cfg.with(Pass::MaddSubFuse),
                "movelim" => cfg.with(Pass::RedundantMovElim),
                "rowsched" => cfg.with(Pass::RowSwitchSchedule),
                other => bail!(
                    "unknown pass or preset '{other}' \
                     (presets: none|base|sw|hw|swhw|all; \
                     passes: pairfuse|twiddle|maddsub|movelim|rowsched)"
                ),
            };
        }
        Ok(cfg)
    }
}

impl From<OptLevel> for PassConfig {
    fn from(opt: OptLevel) -> PassConfig {
        PassConfig::preset(opt)
    }
}

impl std::fmt::Display for PassConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.name())
    }
}

/// What the pipeline did while lowering one stream — the per-pass
/// provenance counters [`crate::pim::ExecReport`] carries, so every figure
/// and ablation can attribute command/slot counts to the pass that shaped
/// them.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PassProvenance {
    /// Butterfly IR ops lowered.
    pub butterflies: u64,
    /// Butterflies strength-reduced to pim-ADD (TwiddleStrengthReduce).
    pub trivial_reduced: u64,
    /// Butterflies taking the §6.3 symmetric ±1/√2 routine.
    pub sqrt2_fused: u64,
    /// Dual-write micro-ops emitted (MaddSubFuse).
    pub dual_writes: u64,
    /// x2-staging pim-MOV commands deleted (RedundantMovElim).
    pub movs_eliminated: u64,
    /// Stages emitted in reversed block order (RowSwitchSchedule).
    pub stages_reversed: u64,
    /// Paired commands split into two singles (BankPairFuse disabled).
    pub pairs_split: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper_levels() {
        assert_eq!(PassConfig::preset(OptLevel::Base).name(), "pim-base");
        assert_eq!(PassConfig::preset(OptLevel::Sw).name(), "sw-opt");
        assert_eq!(PassConfig::preset(OptLevel::Hw).name(), "hw-opt");
        assert_eq!(PassConfig::preset(OptLevel::SwHw).name(), "sw-hw-opt");
        for opt in OptLevel::ALL {
            let p = PassConfig::preset(opt);
            assert!(p.bank_pair_fuse);
            assert_eq!(p.needs_hw(), opt.needs_hw());
            assert_eq!(p.opt_level(), Some(opt));
            assert_eq!(PassConfig::from(opt), p);
        }
    }

    #[test]
    fn with_without_roundtrip() {
        for pass in Pass::ALL {
            let on = PassConfig::NONE.with(pass);
            assert!(on.enabled(pass));
            assert_eq!(on.without(pass), PassConfig::NONE);
        }
        assert_eq!(PassConfig::NONE.passes(), vec![]);
        assert_eq!(
            PassConfig::preset(OptLevel::SwHw).passes(),
            vec![Pass::BankPairFuse, Pass::TwiddleStrengthReduce, Pass::MaddSubFuse]
        );
    }

    #[test]
    fn names_for_extended_sets() {
        let p = PassConfig::preset(OptLevel::SwHw)
            .with(Pass::RedundantMovElim)
            .with(Pass::RowSwitchSchedule);
        assert_eq!(p.name(), "sw-hw-opt+movelim+rowsched");
        assert_eq!(p.opt_level(), None);
        assert_eq!(PassConfig::NONE.name(), "none");
        let odd = PassConfig::NONE.with(Pass::TwiddleStrengthReduce);
        assert_eq!(odd.name(), "twiddle");
    }

    #[test]
    fn parse_specs() {
        assert_eq!(PassConfig::parse("swhw").unwrap(), PassConfig::preset(OptLevel::SwHw));
        assert_eq!(
            PassConfig::parse("sw-hw-opt,movelim").unwrap(),
            PassConfig::preset(OptLevel::SwHw).with(Pass::RedundantMovElim)
        );
        assert_eq!(
            PassConfig::parse("pairfuse+twiddle").unwrap(),
            PassConfig::preset(OptLevel::Sw)
        );
        assert_eq!(PassConfig::parse("none").unwrap(), PassConfig::NONE);
        let all = PassConfig::parse("all").unwrap();
        assert!(Pass::ALL.into_iter().all(|p| all.enabled(p)));
        assert!(PassConfig::parse("turbo").is_err());
    }
}
