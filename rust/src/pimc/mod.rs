//! Pimc — the PIM command-stream compiler: a butterfly-level stream IR plus
//! an optimizing pass pipeline that lowers it to broadcast
//! [`crate::pim::PimCommand`]s.
//!
//! The paper's §6 contributions are *ways to lower the PIM operations a
//! butterfly needs*. Pre-IR, each combination lived as a hand-specialized
//! code path keyed on a closed `OptLevel` enum; here they are independent
//! passes over one IR, so the paper's four evaluation points become four
//! presets in an open configuration space (and per-pass ablations the paper
//! never ran — see the `passes` CLI subcommand).
//!
//! ## The IR
//!
//! Routines emit [`IrOp`]s into an [`IrSink`]: [`BflyOp`] butterflies
//! carrying their stage, §6.1 twiddle class and operand placement, plus
//! explicit `Stage` / `RowOpen` / `ChunkStage` markers describing row
//! locality, and a `Raw` escape hatch for streams the butterfly model does
//! not fit (the Fig 9 baseline mapping). [`PassPipeline`] — itself an
//! `IrSink` — lowers each op into the configured [`crate::pim::Sink`], so
//! generation stays O(1)-memory no matter the tile size.
//!
//! ## The passes
//!
//! | pass ([`Pass`])         | paper | effect |
//! |-------------------------|-------|--------|
//! | `BankPairFuse`          | §2.3 / Fig 6 | even/odd micro-ops of a butterfly retire in one command slot; disabled, every micro-op pays its own slot |
//! | `TwiddleStrengthReduce` | §6.1 (`sw-opt`) | ω ∈ {±1, ±j} butterflies become 4 pim-ADD (2 with the dual-write port) |
//! | `MaddSubFuse`           | §6.2 (`hw-opt`) | selects dual-write MADD+SUB / ADD±SUB ops — 4 compute ops per general butterfly; requires `PimConfig::hw_maddsub` |
//! | `RedundantMovElim`      | — (new) | forwards open-row x2 reads into dual-write consumers, deleting dead staging pim-MOVs (same-half trivial classes, cross-row regime) |
//! | `RowSwitchSchedule`     | — (new) | serpentine block order across stages, starting each stage on the rows the previous one left open (fewer tRP+tRAS charges) |
//!
//! [`PassConfig`] names the sets; `OptLevel::{Base, Sw, Hw, SwHw}` map to
//! the presets `{pairfuse}`, `{pairfuse, twiddle}`, `{pairfuse, maddsub}`,
//! `{pairfuse, twiddle, maddsub}` via [`PassConfig::preset`]. The pipeline
//! records what it did in [`PassProvenance`] counters, which
//! [`crate::pim::ExecReport`] carries alongside the timing buckets.
//!
//! ## Register conventions (strided routines)
//!
//! | reg   | role                                             |
//! |-------|--------------------------------------------------|
//! | r0,r1 | m1, m2 (Fig 14) / AddSub temporaries             |
//! | r2,r3 | reserved                                         |
//! | r4,r5 | d, e (x2 components) staged from the open row    |
//! | r6..  | chunk staging for cross-row stages (x1/y1 re+im) |
//!
//! The register file size (Table 1: 16) bounds the cross-row chunk width —
//! which is exactly why the Fig 19 RF×2 variant helps large tiles.
//!
//! ## Expressing a new routine
//!
//! A routine is any producer of `IrOp`s: walk your butterfly schedule, pick
//! each butterfly's [`X1Loc`] placement (open-row word, or registers staged
//! via `ChunkStage` bursts you emit around it), and hand every op to a
//! `PassPipeline` — encoding, strength reduction, slot packing and
//! provenance accounting are the pipeline's job, not the routine's. See
//! `routines::emit_strided_ir` for the canonical frontend and
//! `routines::emit_baseline` for a `Raw`-op frontend.

mod ir;
mod lower;
mod passes;

pub use ir::{BflyOp, ChunkDir, IrOp, IrSink, Regime, VecIrSink, X1Loc};
pub use lower::PassPipeline;
pub use passes::{Pass, PassConfig, PassProvenance};
