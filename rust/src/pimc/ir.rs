//! The butterfly-level stream IR.
//!
//! Routines describe *what* a stage computes — butterflies with a twiddle
//! class and an operand placement — and leave *how* it is encoded as PIM
//! commands to the [`crate::pimc::PassPipeline`]. IR ops stream through an
//! [`IrSink`] exactly like [`crate::pim::PimCommand`]s stream through a
//! [`crate::pim::Sink`], so a 2^18-point tile lowers in O(1) memory.

use anyhow::Result;

use crate::fft::TwiddleClass;
use crate::pim::PimCommand;

/// Row-locality regime of a stage (butterfly span vs words-per-row).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Regime {
    /// `2^(stage+1) ≤ words_per_row`: each butterfly touches one open row
    /// per bank.
    SameRow,
    /// Wider stages: x1 and x2 live in different rows, so x1/y1 stage
    /// through the register file in chunks.
    CrossRow,
}

/// Where a butterfly's x1 operand lives (y1 replaces it in place).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum X1Loc {
    /// x1 is in the open row at word `w1` (same-row regime): y1 is written
    /// back read-modify-write.
    Row { w1: u32 },
    /// x1 was staged into registers `(a, b)` = (re, im) by a preceding
    /// [`IrOp::ChunkStage`] load (cross-row regime).
    Regs { a: u8, b: u8 },
}

/// One radix-2 butterfly: y1 = x1 + ω·x2, y2 = x1 − ω·x2, with
/// ω = (cos, sin) of class `class`, x2 = the open-row word `w2`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BflyOp {
    /// FFT stage, `0..log2(n)`.
    pub stage: u32,
    /// §6.1 twiddle value class — what TwiddleStrengthReduce keys on.
    pub class: TwiddleClass,
    pub cos: f32,
    pub sin: f32,
    pub regime: Regime,
    pub x1: X1Loc,
    /// Word of x2 (and of y2) in the open row.
    pub w2: u32,
}

/// Direction of a cross-row register-staging burst.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChunkDir {
    /// Rows → registers: stage `count` x1 word-pairs before the butterflies.
    Load,
    /// Registers → rows: drain the chunk's y1 results.
    Drain,
}

/// One op of the stream IR.
#[derive(Debug, Clone, PartialEq)]
pub enum IrOp {
    /// A new stage begins. `reversed` marks RowSwitchSchedule's serpentine
    /// block order (provenance only — the producer already ordered the
    /// butterflies).
    Stage { stage: u32, regime: Regime, reversed: bool },
    /// Cross-row regime: the working set of rows for `block` opens.
    RowOpen { block: u32 },
    /// Cross-row regime: move `count` word-pairs between row words
    /// `base..base+count` and register pairs `(reg0+2k, reg0+2k+1)`.
    ChunkStage { base: u32, count: u32, reg0: u8, dir: ChunkDir },
    /// One butterfly (the pipeline selects its command encoding).
    Bfly(BflyOp),
    /// A pre-encoded command passed through the pipeline untouched except
    /// for slot packing — the escape hatch for streams whose structure the
    /// butterfly IR does not model (the Fig 9 baseline mapping).
    Raw(PimCommand),
}

/// Receives a generated IR stream.
pub trait IrSink {
    fn accept(&mut self, op: &IrOp) -> Result<()>;
}

/// Collects IR ops (tests / inspection of small tiles).
#[derive(Default)]
pub struct VecIrSink(pub Vec<IrOp>);

impl IrSink for VecIrSink {
    fn accept(&mut self, op: &IrOp) -> Result<()> {
        self.0.push(op.clone());
        Ok(())
    }
}
