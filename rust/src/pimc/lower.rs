//! IR → [`PimCommand`] lowering under a [`PassConfig`].
//!
//! [`PassPipeline`] is an [`IrSink`] that streams lowered commands into any
//! [`Sink`] — timing, functional execution, or collection — preserving the
//! O(1)-memory visitation property. Instruction selection per butterfly is
//! exactly the paper's routines (see the pass table in the
//! [module docs](crate::pimc)); the four [`crate::routines::OptLevel`]
//! presets reproduce the pre-IR emitters' streams command for command.

use anyhow::{ensure, Result};

use crate::dram::Half;
use crate::fft::TwiddleClass;
use crate::pim::{CmdKind, MicroOp, Operand, PimCommand, Sink};

use super::ir::{BflyOp, ChunkDir, IrOp, IrSink, X1Loc};
use super::passes::{PassConfig, PassProvenance};

/// The lowering pipeline: applies the configured passes to each IR op and
/// emits the resulting command stream into `sink`.
pub struct PassPipeline<'s> {
    cfg: PassConfig,
    prov: PassProvenance,
    sink: &'s mut dyn Sink,
}

impl<'s> PassPipeline<'s> {
    pub fn new(passes: impl Into<PassConfig>, sink: &'s mut dyn Sink) -> Self {
        Self { cfg: passes.into(), prov: PassProvenance::default(), sink }
    }

    pub fn config(&self) -> PassConfig {
        self.cfg
    }

    /// Per-pass provenance counters accumulated so far.
    pub fn provenance(&self) -> PassProvenance {
        self.prov
    }

    /// Emission point of every lowered command — where BankPairFuse acts.
    /// With the pass disabled, a paired command is split into two singles
    /// (each micro-op pays its own command slot, the pre-Fig-6 strawman).
    fn push_cmd(&mut self, cmd: &PimCommand) -> Result<()> {
        if !self.cfg.bank_pair_fuse {
            if let (Some(even), Some(odd)) = (cmd.even, cmd.odd) {
                self.prov.pairs_split += 1;
                self.sink.accept(&PimCommand::single(cmd.kind, even))?;
                return self.sink.accept(&PimCommand::single(cmd.kind, odd));
            }
        }
        self.sink.accept(cmd)
    }

    fn push_pair(&mut self, kind: CmdKind, even: MicroOp, odd: MicroOp) -> Result<()> {
        self.push_cmd(&PimCommand::pair(kind, even, odd))
    }

    fn push_single(&mut self, kind: CmdKind, op: MicroOp) -> Result<()> {
        self.push_cmd(&PimCommand::single(kind, op))
    }

    /// Load x2 = (d, e) from the open row into (r4, r5).
    fn load_x2(&mut self, w2: u32) -> Result<()> {
        self.push_pair(
            CmdKind::Mov,
            MicroOp::Mov { dst: Operand::Reg(4), src: Operand::Row(Half::Even, w2) },
            MicroOp::Mov { dst: Operand::Reg(5), src: Operand::Row(Half::Odd, w2) },
        )
    }

    fn x1_ops(x1: X1Loc, w2: u32) -> (Operand, Operand, Operand, Operand, Operand, Operand) {
        // (a_src, b_src, y1re_dst, y1im_dst, y2re_dst, y2im_dst)
        match x1 {
            X1Loc::Row { w1 } => (
                Operand::Row(Half::Even, w1),
                Operand::Row(Half::Odd, w1),
                Operand::Row(Half::Even, w1),
                Operand::Row(Half::Odd, w1),
                Operand::Row(Half::Even, w2),
                Operand::Row(Half::Odd, w2),
            ),
            X1Loc::Regs { a, b } => (
                Operand::Reg(a),
                Operand::Reg(b),
                Operand::Reg(a),
                Operand::Reg(b),
                Operand::Row(Half::Even, w2),
                Operand::Row(Half::Odd, w2),
            ),
        }
    }

    /// Select and emit the command encoding of one butterfly (§4.3/§6.x).
    ///
    /// Trivial (strength-reduced) butterflies first stage x2 into (r4, r5) —
    /// their adds combine two words of the *same* bank, which one column
    /// access cannot feed. All other classes read d and e straight from the
    /// open rows: the even/odd words share a column address, so the
    /// broadcast command's single column read per bank feeds both ALU sides
    /// (the bank-pair shared-ALU wiring of Fig 6).
    fn lower_bfly(&mut self, bf: &BflyOp) -> Result<()> {
        self.prov.butterflies += 1;
        let sw = self.cfg.twiddle_strength_reduce;
        let hw = self.cfg.madd_sub_fuse;
        let (a_src, b_src, y1re, y1im, y2re, y2im) = Self::x1_ops(bf.x1, bf.w2);

        // Direct row-buffer operands for x2 = d + j·e.
        let (d, e) = (Operand::Row(Half::Even, bf.w2), Operand::Row(Half::Odd, bf.w2));

        if sw && bf.class.is_trivial() {
            self.prov.trivial_reduced += 1;
            // RedundantMovElim: when x1 sits in registers and the dual-write
            // port computes y1/y2 from one read of (a, x2), the same-half
            // classes (ω = ±1: re pairs with d, im with e) can read x2
            // straight from the open row — the staging MOV pair is dead.
            // ω = ∓j cross-reads the halves (re needs e, im needs d), so the
            // first dual write would clobber the other side's input; those
            // keep the staging.
            let elide = self.cfg.redundant_mov_elim
                && hw
                && matches!(bf.x1, X1Loc::Regs { .. })
                && matches!(bf.class, TwiddleClass::One | TwiddleClass::NegOne);
            let (d, e) = if elide {
                self.prov.movs_eliminated += 1;
                (d, e)
            } else {
                // Stage x2 into registers: the trivial adds pair a (even, w1)
                // with d (even, w2) — two words of one bank.
                self.load_x2(bf.w2)?;
                (Operand::Reg(4), Operand::Reg(5))
            };
            // ω ∈ {1, −1, −j, +j}: ω·x2 ∈ {±(d,e), ±(e,−d)} — adds only.
            // (re_t ± , im_t ±): the value added to (a, b) for y1.
            let (re_t, re_neg, im_t, im_neg) = match bf.class {
                TwiddleClass::One => (d, false, e, false),
                TwiddleClass::NegOne => (d, true, e, true),
                TwiddleClass::NegJ => (e, false, d, true), // ω·x2 = e − j·d
                TwiddleClass::PlusJ => (e, true, d, false),
                _ => unreachable!(),
            };
            if hw {
                // §6.3: one dual-write ADD±SUB pair — 2 compute ops.
                self.prov.dual_writes += 2;
                return self.push_pair(
                    CmdKind::Add,
                    MicroOp::MaddSub {
                        dst_add: y1re,
                        dst_sub: y2re,
                        a: a_src,
                        b: re_t,
                        imm: if re_neg { -1.0 } else { 1.0 },
                    },
                    MicroOp::MaddSub {
                        dst_add: y1im,
                        dst_sub: y2im,
                        a: b_src,
                        b: im_t,
                        imm: if im_neg { -1.0 } else { 1.0 },
                    },
                );
            }
            // §6.1: 4 pim-ADD (y2 first so the RMW of y1 can reuse a/b).
            self.push_pair(
                CmdKind::Add,
                MicroOp::Madd { dst: y2re, a: a_src, b: re_t, imm: if re_neg { 1.0 } else { -1.0 } },
                MicroOp::Madd { dst: y2im, a: b_src, b: im_t, imm: if im_neg { 1.0 } else { -1.0 } },
            )?;
            return self.push_pair(
                CmdKind::Add,
                MicroOp::Madd { dst: y1re, a: a_src, b: re_t, imm: if re_neg { -1.0 } else { 1.0 } },
                MicroOp::Madd { dst: y1im, a: b_src, b: im_t, imm: if im_neg { -1.0 } else { 1.0 } },
            );
        }

        if sw && hw && bf.class == TwiddleClass::Sqrt2 {
            // §6.3 symmetric case: |c| = |s| = 1/√2 and δ = s/c = ±1:
            // m1 = d − δe, m2 = e + δd. One dual-write AddSub yields
            // (d+e, d−e); m1/m2 are ± those values.
            self.prov.sqrt2_fused += 1;
            self.prov.dual_writes += 3;
            let delta = bf.sin / bf.cos; // ±1 up to rounding
            self.push_single(
                CmdKind::Add,
                MicroOp::AddSub { dst_add: Operand::Reg(0), dst_sub: Operand::Reg(1), a: d, b: e },
            )?;
            // r0 = d+e, r1 = d−e.
            // δ = −1: m1 = d+e = r0,  m2 = e−d = −r1.
            // δ = +1: m1 = d−e = r1,  m2 = e+d = r0.
            let (m1_reg, m2_reg, m2_neg) = if delta < 0.0 {
                (Operand::Reg(0), Operand::Reg(1), true)
            } else {
                (Operand::Reg(1), Operand::Reg(0), false)
            };
            return self.push_pair(
                CmdKind::Madd,
                MicroOp::MaddSub { dst_add: y1re, dst_sub: y2re, a: a_src, b: m1_reg, imm: bf.cos },
                MicroOp::MaddSub {
                    dst_add: y1im,
                    dst_sub: y2im,
                    a: b_src,
                    b: m2_reg,
                    imm: if m2_neg { -bf.cos } else { bf.cos },
                },
            );
        }

        // General ω (and the non-reduced fallbacks): Fig 14 right.
        // m1 = d − δ·e, m2 = e + δ·d with δ = s/c (c ≠ 0 away from ±j).
        ensure!(bf.cos.abs() > 1e-30, "general butterfly routine requires cos(ω) != 0");
        let delta = bf.sin / bf.cos;
        self.push_pair(
            CmdKind::Madd,
            MicroOp::Madd { dst: Operand::Reg(0), a: d, b: e, imm: -delta },
            MicroOp::Madd { dst: Operand::Reg(1), a: e, b: d, imm: delta },
        )?;
        if hw {
            // §6.2: dual-write MADD+SUB finishes each component in one op.
            self.prov.dual_writes += 2;
            let c = bf.cos;
            return self.push_pair(
                CmdKind::Madd,
                MicroOp::MaddSub { dst_add: y1re, dst_sub: y2re, a: a_src, b: Operand::Reg(0), imm: c },
                MicroOp::MaddSub { dst_add: y1im, dst_sub: y2im, a: b_src, b: Operand::Reg(1), imm: c },
            );
        }
        self.push_pair(
            CmdKind::Madd,
            MicroOp::Madd { dst: y2re, a: a_src, b: Operand::Reg(0), imm: -bf.cos },
            MicroOp::Madd { dst: y2im, a: b_src, b: Operand::Reg(1), imm: -bf.cos },
        )?;
        self.push_pair(
            CmdKind::Madd,
            MicroOp::Madd { dst: y1re, a: a_src, b: Operand::Reg(0), imm: bf.cos },
            MicroOp::Madd { dst: y1im, a: b_src, b: Operand::Reg(1), imm: bf.cos },
        )
    }

    /// Lower a cross-row staging burst to pim-MOV pairs.
    fn lower_chunk(&mut self, base: u32, count: u32, reg0: u8, dir: ChunkDir) -> Result<()> {
        for k in 0..count {
            let w = base + k;
            let ra = reg0 + 2 * k as u8;
            let rb = ra + 1;
            match dir {
                ChunkDir::Load => self.push_pair(
                    CmdKind::Mov,
                    MicroOp::Mov { dst: Operand::Reg(ra), src: Operand::Row(Half::Even, w) },
                    MicroOp::Mov { dst: Operand::Reg(rb), src: Operand::Row(Half::Odd, w) },
                )?,
                ChunkDir::Drain => self.push_pair(
                    CmdKind::Mov,
                    MicroOp::Mov { dst: Operand::Row(Half::Even, w), src: Operand::Reg(ra) },
                    MicroOp::Mov { dst: Operand::Row(Half::Odd, w), src: Operand::Reg(rb) },
                )?,
            }
        }
        Ok(())
    }
}

impl IrSink for PassPipeline<'_> {
    fn accept(&mut self, op: &IrOp) -> Result<()> {
        match op {
            IrOp::Stage { reversed, .. } => {
                if *reversed {
                    self.prov.stages_reversed += 1;
                }
                Ok(())
            }
            IrOp::RowOpen { .. } => Ok(()),
            IrOp::ChunkStage { base, count, reg0, dir } => {
                self.lower_chunk(*base, *count, *reg0, *dir)
            }
            IrOp::Bfly(bf) => self.lower_bfly(bf),
            IrOp::Raw(cmd) => self.push_cmd(cmd),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pim::VecSink;
    use crate::pimc::Regime;
    use crate::routines::OptLevel;

    fn bfly(class: TwiddleClass, cos: f32, sin: f32, x1: X1Loc, w2: u32) -> IrOp {
        IrOp::Bfly(BflyOp { stage: 0, class, cos, sin, regime: Regime::CrossRow, x1, w2 })
    }

    #[test]
    fn preset_encodings_have_paper_command_counts() {
        // One general butterfly: 3 commands at base, 2 at hw.
        let g = bfly(TwiddleClass::General, 0.9, -0.43, X1Loc::Row { w1: 0 }, 4);
        for (opt, want) in [(OptLevel::Base, 3), (OptLevel::Hw, 2)] {
            let mut v = VecSink::default();
            let mut p = PassPipeline::new(opt, &mut v);
            p.accept(&g).unwrap();
            assert_eq!(v.0.len(), want, "{opt}");
        }
        // One trivial butterfly: mov + 2 adds at sw, mov + 1 dual-write at
        // sw-hw.
        let t = bfly(TwiddleClass::One, 1.0, 0.0, X1Loc::Row { w1: 0 }, 4);
        for (opt, want) in [(OptLevel::Sw, 3), (OptLevel::SwHw, 2)] {
            let mut v = VecSink::default();
            let mut p = PassPipeline::new(opt, &mut v);
            p.accept(&t).unwrap();
            assert_eq!(v.0.len(), want, "{opt}");
        }
    }

    #[test]
    fn pair_split_without_bank_pair_fuse() {
        let g = bfly(TwiddleClass::General, 0.9, -0.43, X1Loc::Row { w1: 0 }, 4);
        let mut v = VecSink::default();
        let mut p = PassPipeline::new(PassConfig::NONE, &mut v);
        p.accept(&g).unwrap();
        let prov = p.provenance();
        // 3 pairs split into 6 singles.
        assert_eq!(prov.pairs_split, 3);
        assert_eq!(v.0.len(), 6);
        assert!(v.0.iter().all(|c| c.op_count() == 1));
    }

    #[test]
    fn movelim_elides_staging_for_same_half_trivials_only() {
        let elim = PassConfig::preset(OptLevel::SwHw).with(crate::pimc::Pass::RedundantMovElim);
        // ω = 1 with x1 in registers: staging MOV disappears.
        let one = bfly(TwiddleClass::One, 1.0, 0.0, X1Loc::Regs { a: 6, b: 7 }, 4);
        let mut v = VecSink::default();
        let mut p = PassPipeline::new(elim, &mut v);
        p.accept(&one).unwrap();
        assert_eq!(p.provenance().movs_eliminated, 1);
        assert_eq!(v.0.len(), 1);
        // ω = −j cross-reads the halves: staging must stay.
        let negj = bfly(TwiddleClass::NegJ, 0.0, -1.0, X1Loc::Regs { a: 6, b: 7 }, 4);
        let mut v = VecSink::default();
        let mut p = PassPipeline::new(elim, &mut v);
        p.accept(&negj).unwrap();
        assert_eq!(p.provenance().movs_eliminated, 0);
        assert_eq!(v.0.len(), 2);
        // Same-row x1 would need two column reads: staging must stay too.
        let row = bfly(TwiddleClass::One, 1.0, 0.0, X1Loc::Row { w1: 0 }, 4);
        let mut v = VecSink::default();
        let mut p = PassPipeline::new(elim, &mut v);
        p.accept(&row).unwrap();
        assert_eq!(p.provenance().movs_eliminated, 0);
        assert_eq!(v.0.len(), 2);
    }

    #[test]
    fn provenance_counts_selections() {
        let mut v = VecSink::default();
        let mut p = PassPipeline::new(OptLevel::SwHw, &mut v);
        p.accept(&bfly(TwiddleClass::One, 1.0, 0.0, X1Loc::Row { w1: 0 }, 4)).unwrap();
        p.accept(&bfly(
            TwiddleClass::Sqrt2,
            std::f32::consts::FRAC_1_SQRT_2,
            -std::f32::consts::FRAC_1_SQRT_2,
            X1Loc::Row { w1: 0 },
            4,
        ))
        .unwrap();
        p.accept(&bfly(TwiddleClass::General, 0.9, -0.43, X1Loc::Row { w1: 0 }, 4)).unwrap();
        let prov = p.provenance();
        assert_eq!(prov.butterflies, 3);
        assert_eq!(prov.trivial_reduced, 1);
        assert_eq!(prov.sqrt2_fused, 1);
        // 2 (trivial) + 3 (sqrt2: AddSub + MaddSub pair) + 2 (general).
        assert_eq!(prov.dual_writes, 7);
    }
}
