//! # Pimacolaba — collaborative GPU+PIM acceleration of FFT
//!
//! Production-shaped reproduction of *"Collaborative Acceleration for FFT on
//! Commercial Processing-In-Memory Architectures"* (Ibrahim & Aga, 2023).
//!
//! The paper maps radix-2 complex FFT onto a strawman commercial HBM-PIM
//! design, finds whole-FFT offload loses to a memory-bandwidth-bound GPU
//! (≈52% average slowdown), and recovers acceleration (≤1.38×) plus data
//! movement savings (≤2.76×) by **collaborative decomposition**: the GPU
//! executes the large FFT factor, the PIM executes a small *PIM-FFT-Tile*
//! factor with twiddle-aware software routines (`sw-opt`) and a MADD+SUB ALU
//! augmentation (`hw-opt`).
//!
//! ## Crate layout (engine/backend architecture)
//!
//! Execution is organized around a unified engine with pluggable substrate
//! backends:
//!
//! * [`backend`] — the heart of the crate. [`backend::FftEngine`]
//!   (builder-configured, with a memoized plan cache keyed by
//!   `(n, batch, pass set)`) plans, costs and executes FFTs through the
//!   [`backend::ComputeBackend`] trait: `estimate` models a plan component
//!   (time + data movement), `execute` computes real spectra. Concrete
//!   backends: [`backend::HostFftBackend`] (reference FFT),
//!   [`backend::PjrtGpuBackend`] (AOT artifacts over PJRT),
//!   [`backend::PimSimBackend`] (functional PIM unit simulator), and
//!   [`device::DeviceBackend`] (stage-dispatch device queue), with
//!   [`backend::GpuCostModel`] selecting the analytical or measured GPU
//!   cost provider.
//! * [`device`] — the stage-dispatch device backend: lowers GPU plan
//!   components into explicit [`device::DeviceProgram`]s (numbered buffers,
//!   per-dispatch bind lists + uniform blocks, one dispatch per LDS kernel
//!   pass) and executes them on the thread pool as a device queue, with a
//!   [`device::MovementLedger`] whose executed per-dispatch byte counts
//!   reconcile **exactly** against [`gpu_model::gpu_pass_bytes`] — the seam
//!   where a real wgpu/PJRT queue plugs in later. Select it with
//!   `FftEngine::builder().device()` or `--backend device`; audit it with
//!   the `device-audit` CLI subcommand.
//! * [`coordinator`] — **L3**: the FFT service. Routing, batching (round-
//!   robin across FFT sizes, so large requests are never starved), hybrid
//!   plan execution through the engine, metrics, and open-loop workload
//!   generation ([`coordinator::Workload`]: Poisson/burst/diurnal arrivals
//!   × size-mix profiles). Python is never on this path, and no substrate
//!   is touched except through a backend.
//! * [`cluster`] — **L4**: the deterministic discrete-event cluster
//!   simulator. N shards, each owning its own engine, serve millions of
//!   trace requests in virtual time with windowed batching and pluggable
//!   routing (round-robin / size-affinity / least-loaded); the SLO-aware
//!   capacity planner ([`cluster::plan_capacity`]) binary-searches the
//!   minimal shard count meeting a p99 latency target. Reports carry
//!   log-bucketed latency percentiles ([`metrics::LogHistogram`]),
//!   per-shard utilization, and per-substrate data movement, and are
//!   emitted as JSON artifacts by the `cluster` CLI subcommand.
//! * [`workload`] — multi-workload serving (§7.1): [`workload::WorkloadKind`]
//!   (batched 1D / 2D / 3D / real / circular convolution / STFT) decomposes
//!   every request kind into the batched 1D FFT passes the engine plans and
//!   executes ([`backend::FftEngine::plan_workload`] /
//!   [`backend::FftEngine::run_workload`]), with transposes, pack/unpack,
//!   and pointwise products priced as data movement;
//!   [`workload::KindMix`] drives mixed-kind traffic through the trace
//!   generator and the cluster simulator (`cluster --workload-mix`, and the
//!   per-kind `workload` CLI report).
//! * [`serve`] — **L5**: the online serving tier. A reactor thread plus
//!   per-shard engine workers serve live requests (in-process
//!   [`serve::LiveClient`] or the length-prefixed localhost socket in
//!   [`serve::protocol`]) with token-bucket + max-inflight admission
//!   control, bounded per-shard queues that reject with a retry-after
//!   hint, deadline-aware EDF batch dispatch (drop or degrade infeasible
//!   requests, accounted separately), and hedged retries across shards.
//!   The closed-loop harness (`serve-live --harness`,
//!   [`serve::run_harness`]) drives millions of requests through real
//!   threads and sockets and emits a [`serve::LiveReport`] whose JSON is
//!   a key-compatible superset of the cluster simulator's report.
//! * [`planner`] — collaborative decomposition (§5.1): plan selection via
//!   the offline tile-efficiency table; its cost evaluation is built from
//!   the same providers the backends use.
//! * [`runtime`] — the execution runtime: [`runtime::ThreadPool`], a
//!   work-stealing pool (std threads only) behind every `--threads N`
//!   surface — batch-parallel 1D passes in the host backend, fanned-out
//!   workload transposes/gathers in the engine, and parallel plan
//!   pre-warming in the cluster simulator — selected by a
//!   [`runtime::Parallelism`] knob and bit-deterministic across thread
//!   counts. Also the PJRT glue: loads `artifacts/*.hlo.txt` (AOT-lowered
//!   from the L2 jax model, which calls the L1 Pallas butterfly kernel);
//!   the XLA bindings are gated behind the `pjrt` cargo feature; without it
//!   the registry still parses manifests but execution falls back to the
//!   host backend.
//! * [`pimc`] — the PIM stream compiler: routines emit a butterfly-level
//!   IR; [`pimc::PassPipeline`] lowers it to command streams under a
//!   [`pimc::PassConfig`] of composable optimization passes (the paper's
//!   `sw-opt`/`hw-opt` plus new ones), with per-pass provenance counters.
//! * Substrates the paper depends on, all built here:
//!   [`dram`] (command-level HBM timing), [`pim`] (functional + timing PIM
//!   unit simulator), [`mapping`] (strided/baseline data layouts),
//!   [`routines`] (PIM FFT IR frontends), [`gpu_model`]
//!   (the paper's analytical GPU model and a "measured" GPU simulator),
//!   [`fft`] (host reference FFT + four-step algebra).
//! * [`figures`] — one generator per paper figure/table, all driven through
//!   the engine; used by the benches and the `figures` CLI subcommand.

pub mod backend;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod device;
pub mod dram;
pub mod fft;
pub mod figures;
pub mod gpu_model;
pub mod mapping;
pub mod metrics;
pub mod obs;
pub mod pim;
pub mod pimc;
pub mod planner;
pub mod routines;
pub mod runtime;
pub mod serve;
pub mod util;
pub mod workload;

/// Crate-wide result type (anyhow-backed).
pub type Result<T> = anyhow::Result<T>;
