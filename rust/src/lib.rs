//! # Pimacolaba — collaborative GPU+PIM acceleration of FFT
//!
//! Production-shaped reproduction of *"Collaborative Acceleration for FFT on
//! Commercial Processing-In-Memory Architectures"* (Ibrahim & Aga, 2023).
//!
//! The paper maps radix-2 complex FFT onto a strawman commercial HBM-PIM
//! design, finds whole-FFT offload loses to a memory-bandwidth-bound GPU
//! (≈52% average slowdown), and recovers acceleration (≤1.38×) plus data
//! movement savings (≤2.76×) by **collaborative decomposition**: the GPU
//! executes the large FFT factor, the PIM executes a small *PIM-FFT-Tile*
//! factor with twiddle-aware software routines (`sw-opt`) and a MADD+SUB ALU
//! augmentation (`hw-opt`).
//!
//! ## Crate layout (three-layer architecture)
//!
//! * [`coordinator`] — **L3**: the FFT service. Routing, batching, hybrid
//!   plan execution, metrics. Python is never on this path.
//! * [`runtime`] — PJRT glue: loads `artifacts/*.hlo.txt` (AOT-lowered from
//!   the L2 jax model, which calls the L1 Pallas butterfly kernel) and
//!   executes them on the CPU client.
//! * Substrates the paper depends on, all built here:
//!   [`dram`] (command-level HBM timing), [`pim`] (functional + timing PIM
//!   unit simulator), [`mapping`] (strided/baseline data layouts),
//!   [`routines`] (PIM FFT command-stream generators), [`gpu_model`]
//!   (the paper's analytical GPU model and a "measured" GPU simulator),
//!   [`planner`] (collaborative decomposition), [`fft`] (host reference
//!   FFT + four-step algebra).
//! * [`figures`] — one generator per paper figure/table; used by the
//!   criterion benches and the `figures` CLI subcommand.

pub mod config;
pub mod coordinator;
pub mod dram;
pub mod fft;
pub mod figures;
pub mod gpu_model;
pub mod mapping;
pub mod metrics;
pub mod pim;
pub mod planner;
pub mod routines;
pub mod runtime;
pub mod util;

/// Crate-wide result type (anyhow-backed).
pub type Result<T> = anyhow::Result<T>;
