//! The cluster simulator: a heterogeneous, failure-prone fleet serving an
//! open-loop trace in virtual time.
//!
//! Mechanics per shard (mirroring the live [`crate::coordinator::Server`]
//! loop, but in virtual time): arrivals are routed by the configured
//! [`RouterKind`] and queued size-homogeneously; a shard with a free batch
//! slot dispatches as soon as one size accumulates `window_signals`, or
//! when the `max_wait_us` batching window expires; completions drain
//! whatever accumulated while the slot was occupied (work-conserving).
//! Service time is the engine's modeled cost for the padded batch shape, so
//! the simulation prices exactly what the paper's models price — and a run
//! over millions of requests finishes in wall-clock seconds because no
//! spectra are ever computed.
//!
//! ## Heterogeneous fleets ([`ClusterConfig::fleet`])
//!
//! Each shard is built from a [`ShardSpec`]: its engine prices on the
//! spec's mutated `SystemConfig` (stack count, PIM density), `GpuOnly`
//! shards serve at the GPU-baseline time instead of the collaborative
//! plan, and `threads` batch slots serve concurrently. An empty fleet is
//! `shards` copies of the paper baseline — bit-identical to the historical
//! homogeneous simulator.
//!
//! ## Fault injection ([`ClusterConfig::faults`])
//!
//! A [`FaultPlan`] decides — entirely up front, from its own seed — a
//! crash/restart timeline per shard and a straggler multiplier per shard.
//! Crashes abort in-flight batches (requeue or fail per the plan's mode),
//! downed shards keep queueing but dispatch nothing until their restart,
//! and the report grows a `failures` section under the extended
//! conservation law `served + failed == submitted`.
//!
//! ## Parallel stepping ([`ClusterConfig::threads`])
//!
//! The expensive per-event work is plan evaluation (a cache miss runs the
//! §5.1 planner and the PIM tile model); the event core itself is cheap
//! bookkeeping. With `threads > 1` the run splits accordingly: **workers
//! compute, the event core commits.** [`warm_plans`] enumerates every plan
//! shape the trace can dispatch (each `(kind, n)` × the power-of-two padded
//! batch ladder) and evaluates them across the pool before virtual time
//! starts — once per *distinct* shard system in the fleet; the
//! single-threaded event core then pops events in deterministic FIFO order
//! and finds every plan pre-computed. Because each warm entry is exactly
//! the value an unwarmed engine would compute (same planner, same
//! deterministic float path — see `FftEngineBuilder::warm_plans`), reports
//! stay **bit-identical per seed for every thread count** — fault
//! timelines included, since they never depend on evaluation order — which
//! `rust/tests/parallel_runtime.rs` pins byte-for-byte.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{ensure, Result};

use crate::backend::{EngineBackend, FftEngine, PassAttribution, WarmPlans};
use crate::config::SystemConfig;
use crate::coordinator::Trace;
use crate::metrics::{depth_json, latency_us_json, plan_cache_json, DataMovement, LogHistogram};
use crate::obs::{reason, Exemplar, Obs, SpanRecord, VirtualClock};
use crate::pimc::PassConfig;
use crate::routines::OptLevel;
use crate::runtime::Parallelism;
use crate::util::Json;
use crate::workload::{per_kind_json, WorkloadKind};

use super::event::{Event, EventQueue};
use super::fault::{CrashMode, FailureSummary, FaultPlan};
use super::fleet::ShardSpec;
use super::router::RouterKind;
use super::shard::{Shard, SimRequest};

/// Fixed observability policy: every 64th trace-entry id gets a span
/// timeline; the flight recorder retains the last 256. Constants (not
/// knobs) so the registry/exemplar state — and therefore the report —
/// stays bit-identical per seed whether or not tracing is on.
const CLUSTER_TRACE_SAMPLE: u64 = 64;
const CLUSTER_RECORDER_CAP: usize = 256;

/// Cluster shape and batching policy.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Homogeneous shard count, used only when `fleet` is empty.
    pub shards: usize,
    pub router: RouterKind,
    /// Heterogeneous fleet: one [`ShardSpec`] per shard, in order. Empty
    /// means `shards` copies of [`ShardSpec::mixed`] (the paper baseline),
    /// which reproduces the historical homogeneous simulator bit for bit.
    pub fleet: Vec<ShardSpec>,
    /// Seeded fault injection (crashes/restarts, stragglers). `None` runs
    /// the fault-free simulator unchanged.
    pub faults: Option<FaultPlan>,
    /// Dispatch a batch as soon as one size queue holds this many signals.
    pub window_signals: usize,
    /// Longest a queued request waits before an idle shard serves a partial
    /// batch, µs.
    pub max_wait_us: f64,
    pub sys: SystemConfig,
    /// PIM lowering pass set every shard engine is built with.
    pub passes: PassConfig,
    /// GPU execution substrate every shard engine runs on: the fast host
    /// kernels (default) or the audited stage-dispatch device queue.
    /// Reports are identical under both — execution here only prices plans
    /// — but numeric smoke paths and the plan table go through the
    /// selected backend.
    pub backend: EngineBackend,
    /// Plan evaluation parallelism (see the module docs): workers
    /// pre-compute the plan table, the event core commits sequentially.
    /// Reports are bit-identical for every setting.
    pub threads: Parallelism,
    /// Pre-computed plan table shared across runs, for shards whose spec
    /// leaves `sys` untouched. The table depends only on the trace and the
    /// engine config — never on the shard count — so callers that simulate
    /// one trace many times (the capacity planner's probes) compute it once
    /// with [`warm_plans`] and set it here; `None` with `threads > 1`
    /// computes it per run (and per distinct fleet system).
    pub warm: Option<Arc<WarmPlans>>,
    /// Collect Chrome-traceable span events for sampled requests (the
    /// `cluster --trace-out` path). Gates ONLY the trace buffer: metrics
    /// and exemplars are always maintained on the virtual clock, so the
    /// report is bit-identical with this on or off.
    pub trace: bool,
}

impl ClusterConfig {
    pub fn new(sys: SystemConfig, passes: impl Into<PassConfig>) -> Self {
        Self {
            shards: 4,
            router: RouterKind::SizeAffinity,
            fleet: Vec::new(),
            faults: None,
            window_signals: 32,
            max_wait_us: 50.0,
            sys,
            passes: passes.into(),
            backend: EngineBackend::default(),
            threads: Parallelism::Sequential,
            warm: None,
            trace: false,
        }
    }

    /// Paper-baseline system with the §6.2 hardware optimization (the full
    /// Pimacolaba configuration).
    pub fn default_hw() -> Self {
        Self::new(SystemConfig::baseline().with_hw_opt(), OptLevel::SwHw)
    }

    /// The per-shard specs this config actually simulates: `fleet` as
    /// given, or `shards` paper-baseline shards when no fleet is set.
    pub fn effective_fleet(&self) -> Vec<ShardSpec> {
        if self.fleet.is_empty() {
            vec![ShardSpec::mixed(); self.shards]
        } else {
            self.fleet.clone()
        }
    }
}

/// Per-shard rollup in the final report.
#[derive(Debug, Clone)]
pub struct ShardSummary {
    pub shard: usize,
    /// Device class name from the shard's [`ShardSpec`].
    pub class: &'static str,
    pub requests: u64,
    pub signals: u64,
    pub batches: u64,
    pub busy_ns: u64,
    /// Fraction of the makespan this shard spent serving.
    pub utilization: f64,
    pub movement: DataMovement,
    pub cache_hits: u64,
    pub cache_misses: u64,
}

/// Everything a cluster run produces. `to_json` is the report artifact the
/// `cluster` CLI subcommand writes; identical seeds/configs produce
/// byte-identical JSON.
#[derive(Debug, Clone)]
pub struct ClusterReport {
    pub shards: usize,
    pub router: &'static str,
    /// GPU execution substrate the shard engines were built on.
    pub backend: &'static str,
    pub requests: u64,
    pub signals: u64,
    pub padded_signals: u64,
    pub batches: u64,
    /// Virtual time from trace start to the last completion, ns.
    pub makespan_ns: u64,
    /// End-to-end request latency (arrival → completion), ns.
    pub latency_ns: LogHistogram,
    /// Queue depth sampled at every arrival, merged across shards.
    pub queue_depth: LogHistogram,
    /// Batch occupancy (percent of the padded shape used).
    pub occupancy_pct: LogHistogram,
    /// Per-substrate data movement summed over every executed plan.
    pub movement: DataMovement,
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Requests served per workload kind (mixed-workload traffic).
    pub per_kind: BTreeMap<WorkloadKind, u64>,
    pub per_shard: Vec<ShardSummary>,
    /// Fault accounting: crashes, restarts, requeues, failed requests, and
    /// straggler exposure. All zeros on a fault-free run.
    pub failures: FailureSummary,
    /// 16-hex FNV digest of the run's metrics-registry exposition —
    /// deterministic per seed, pinned to prove tracing doesn't perturb it.
    pub obs_digest: String,
    /// Exemplar timelines retained in the flight recorder.
    pub obs_exemplars: u64,
}

impl ClusterReport {
    /// Latency percentile in µs.
    pub fn latency_p_us(&self, p: f64) -> f64 {
        self.latency_ns.percentile(p) as f64 / 1e3
    }

    /// Served throughput over the makespan, requests/s.
    pub fn throughput_rps(&self) -> f64 {
        if self.makespan_ns == 0 {
            0.0
        } else {
            self.requests as f64 / (self.makespan_ns as f64 / 1e9)
        }
    }

    /// Aggregate plan-cache hit rate across shard engines.
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    /// Mean batch occupancy (served signals / padded signals).
    pub fn avg_occupancy(&self) -> f64 {
        if self.padded_signals == 0 {
            0.0
        } else {
            self.signals as f64 / self.padded_signals as f64
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "shards={} router={} requests={} throughput={:.0}req/s p50={:.1}µs p95={:.1}µs \
             p99={:.1}µs p999={:.1}µs occupancy={:.0}% cache-hit={:.1}% movement={:.1}MB",
            self.shards,
            self.router,
            self.requests,
            self.throughput_rps(),
            self.latency_p_us(50.0),
            self.latency_p_us(95.0),
            self.latency_p_us(99.0),
            self.latency_p_us(99.9),
            self.avg_occupancy() * 100.0,
            self.cache_hit_rate() * 100.0,
            self.movement.total() / 1e6,
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("shards", Json::num(self.shards as f64)),
            ("router", Json::str(self.router)),
            ("backend", Json::str(self.backend)),
            ("requests", Json::num(self.requests as f64)),
            ("signals", Json::num(self.signals as f64)),
            ("padded_signals", Json::num(self.padded_signals as f64)),
            ("batches", Json::num(self.batches as f64)),
            ("makespan_us", Json::num(self.makespan_ns as f64 / 1e3)),
            ("throughput_rps", Json::num(self.throughput_rps())),
            // The shared metric blocks below are the schema contract with
            // the live serving tier's report (`serve::LiveReport::to_json`).
            ("latency_us", latency_us_json(&self.latency_ns)),
            ("queue_depth", depth_json(&self.queue_depth)),
            (
                "batch_occupancy_pct",
                Json::obj(vec![
                    ("avg", Json::num(self.avg_occupancy() * 100.0)),
                    ("p50", Json::num(self.occupancy_pct.percentile(50.0) as f64)),
                    ("p99", Json::num(self.occupancy_pct.percentile(99.0) as f64)),
                ]),
            ),
            ("movement", self.movement.to_json_mb()),
            ("plan_cache", plan_cache_json(self.cache_hits, self.cache_misses)),
            ("per_kind", per_kind_json(&self.per_kind)),
            (
                "per_shard",
                Json::arr(
                    self.per_shard
                        .iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("shard", Json::num(s.shard as f64)),
                                ("class", Json::str(s.class)),
                                ("requests", Json::num(s.requests as f64)),
                                ("signals", Json::num(s.signals as f64)),
                                ("batches", Json::num(s.batches as f64)),
                                ("busy_us", Json::num(s.busy_ns as f64 / 1e3)),
                                ("utilization", Json::num(s.utilization)),
                                ("gpu_mb", Json::num(s.movement.gpu_bytes / 1e6)),
                                ("pim_cmd_mb", Json::num(s.movement.pim_cmd_bytes / 1e6)),
                                ("cache_hits", Json::num(s.cache_hits as f64)),
                                ("cache_misses", Json::num(s.cache_misses as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("failures", self.failures.to_json()),
            (
                "obs",
                Json::obj(vec![
                    ("metrics_digest", Json::str(self.obs_digest.clone())),
                    ("exemplars", Json::num(self.obs_exemplars as f64)),
                ]),
            ),
        ])
    }
}

struct SimArrival {
    at_ns: u64,
    kind: WorkloadKind,
    n: usize,
    signals: usize,
}

/// Pre-compute, across `cfg.threads` workers, every plan-cache entry the
/// simulation can demand of an engine configured with `cfg.sys`: each
/// distinct `(kind, n)` in the trace × the power-of-two padded batch ladder
/// up to that shape's total signal count (batches are padded to the next
/// power of two, so no other batch size can ever be dispatched). Entries
/// are evaluated by scratch engines configured exactly like the shard
/// engines, so each value is bit-identical to what a shard would compute on
/// a cold miss — warming changes wall-clock time, never the report.
pub fn warm_plans(trace: &Trace, cfg: &ClusterConfig) -> Result<WarmPlans> {
    warm_plans_for(trace, cfg, &cfg.sys)
}

/// [`warm_plans`] against an explicit engine system — what a heterogeneous
/// fleet needs: one warm table per *distinct* shard [`SystemConfig`], since
/// the same plan key prices differently under different stack counts or
/// PIM densities.
pub fn warm_plans_for(trace: &Trace, cfg: &ClusterConfig, sys: &SystemConfig) -> Result<WarmPlans> {
    let mut totals: BTreeMap<(WorkloadKind, usize), u64> = BTreeMap::new();
    for e in &trace.entries {
        *totals.entry((e.kind, e.n)).or_insert(0) += e.batch as u64;
    }
    // Every `plan()` key a dispatch could touch, deduplicated.
    let mut keys: std::collections::BTreeSet<(usize, usize)> = std::collections::BTreeSet::new();
    for (&(kind, n), &total) in &totals {
        let mult = kind.signal_multiple();
        let mut padded = 1usize;
        loop {
            if padded % mult == 0 && padded / mult > 0 {
                let units = padded / mult;
                for p in kind.passes(n)? {
                    keys.insert((p.fft_n, p.ffts_per_unit * units));
                }
            }
            if padded as u64 >= total {
                break;
            }
            padded *= 2;
        }
    }
    let keys: Vec<(usize, usize)> = keys.into_iter().collect();
    let scratch = |chunk: &[(usize, usize)]| {
        let mut engine = FftEngine::builder()
            .system(sys)
            .passes(cfg.passes)
            .backend(cfg.backend)
            .build();
        let mut out = Vec::with_capacity(chunk.len());
        for &(n, batch) in chunk {
            if let Ok(hit) = engine.plan(n, batch) {
                out.push(((n, batch, cfg.passes), hit));
            }
        }
        out
    };
    let entries: Vec<_> = match cfg.threads.pool() {
        Some(pool) if keys.len() > 1 => {
            let chunk = keys.len().div_ceil(pool.threads() * 4).max(1);
            let chunks: Vec<&[(usize, usize)]> = keys.chunks(chunk).collect();
            pool.map_indexed(chunks.len(), |i| scratch(chunks[i])).into_iter().flatten().collect()
        }
        _ => scratch(&keys),
    };
    Ok(entries.into_iter().collect())
}

/// Run the cluster simulation over `trace`. Deterministic: same trace +
/// config ⇒ bit-identical report.
pub fn run_cluster(trace: &Trace, cfg: &ClusterConfig) -> Result<ClusterReport> {
    run_cluster_traced(trace, cfg).map(|(report, _)| report)
}

/// Start batches on shard `s` until its slots are full or nothing ready
/// holds `min_signals`, scheduling a `Complete` (stamped with the shard's
/// crash epoch) for each. Returns whether anything dispatched.
fn fill_slots(
    shards: &mut [Shard],
    s: usize,
    now: u64,
    min_signals: usize,
    evq: &mut EventQueue,
) -> Result<bool> {
    let mut started = false;
    while let Some((slot, service)) = shards[s].start_batch(now, min_signals)? {
        let epoch = shards[s].epoch;
        evq.push(now + service, Event::Complete { shard: s, slot, epoch });
        started = true;
    }
    Ok(started)
}

/// [`run_cluster`] plus the observability pipeline it drove: the metrics
/// registry, the flight recorder's exemplars, and — when `cfg.trace` is on
/// — the Chrome-traceable span buffer (virtual-time timestamps), which the
/// `cluster --trace-out` CLI writes out via [`crate::obs::chrome_trace`].
pub fn run_cluster_traced(trace: &Trace, cfg: &ClusterConfig) -> Result<(ClusterReport, Obs)> {
    let fleet = cfg.effective_fleet();
    ensure!(!fleet.is_empty(), "cluster needs at least one shard");
    for spec in &fleet {
        spec.validate()?;
    }
    if let Some(f) = &cfg.faults {
        f.validate()?;
    }
    ensure!(cfg.window_signals >= 1, "batching window must be at least 1 signal");
    ensure!(
        cfg.max_wait_us.is_finite() && cfg.max_wait_us >= 0.0,
        "max wait must be finite and non-negative, got {}",
        cfg.max_wait_us
    );
    ensure!(!trace.entries.is_empty(), "cannot simulate an empty trace");

    let arrivals: Vec<SimArrival> = trace
        .entries
        .iter()
        .map(|e| SimArrival {
            at_ns: (e.at_us * 1e3).round() as u64,
            kind: e.kind,
            n: e.n,
            signals: e.batch,
        })
        .collect();
    let wait_ns = (cfg.max_wait_us * 1e3).round() as u64;

    // Workers compute, the event core commits: with threads > 1 every plan
    // shape is evaluated across the pool up front — once per distinct shard
    // system — so the deterministic FIFO event loop below never blocks on a
    // planner run (see module docs).
    let systems: Vec<SystemConfig> = fleet.iter().map(|spec| spec.system(&cfg.sys)).collect();
    let threaded = !matches!(cfg.threads, Parallelism::Sequential);
    let mut warm_tables: Vec<Option<Arc<WarmPlans>>> = Vec::with_capacity(fleet.len());
    {
        let mut cache: Vec<(&SystemConfig, Option<Arc<WarmPlans>>)> = Vec::new();
        for sys in &systems {
            if let Some((_, w)) = cache.iter().find(|(cached, _)| *cached == sys) {
                warm_tables.push(w.clone());
                continue;
            }
            let w = if *sys == cfg.sys && cfg.warm.is_some() {
                cfg.warm.clone()
            } else if threaded {
                Some(Arc::new(warm_plans_for(trace, cfg, sys)?))
            } else {
                None
            };
            cache.push((sys, w.clone()));
            warm_tables.push(w);
        }
    }

    // Fault decisions are pure functions of the plan + fleet size, fixed
    // before virtual time starts (determinism across `--threads`).
    let stragglers: Vec<f64> = match &cfg.faults {
        Some(f) => f.straggler_multipliers(fleet.len()),
        None => vec![1.0; fleet.len()],
    };
    let crash_mode = cfg.faults.as_ref().map(|f| f.mode).unwrap_or(CrashMode::Requeue);

    let mut shards: Vec<Shard> = fleet
        .iter()
        .enumerate()
        .map(|(i, spec)| {
            let mut b = FftEngine::builder()
                .system(&systems[i])
                .passes(cfg.passes)
                .backend(cfg.backend);
            if let Some(w) = &warm_tables[i] {
                b = b.warm_plans(Arc::clone(w));
            }
            Shard::with_spec(b.build(), *spec, stragglers[i])
        })
        .collect();
    let mut router = cfg.router.build(fleet.len());
    let mut latency = LogHistogram::new();
    let mut failures = FailureSummary::default();
    let mut evq = EventQueue::new();
    evq.push(arrivals[0].at_ns, Event::Arrival { idx: 0 });
    if let Some(f) = &cfg.faults {
        // Horizon = last arrival: crashes during the final drain would only
        // delay completions the schedule can no longer observe anyway.
        let horizon_ns = arrivals.last().map(|a| a.at_ns).unwrap_or(0);
        for (at_ns, shard, is_restart) in f.crash_schedule(fleet.len(), horizon_ns) {
            let ev =
                if is_restart { Event::Restart { shard } } else { Event::Crash { shard } };
            evq.push(at_ns, ev);
        }
    }

    // The simulator drives the shared observability pipeline from its own
    // event queue: the injected VirtualClock reads whatever `now` the last
    // popped event carried, so every span/exemplar timestamp is virtual
    // time. Metrics and exemplars are always on (fixed policy, virtual
    // timestamps only — fully deterministic); `cfg.trace` gates only
    // whether Chrome-trace events accumulate. Fault counters are created
    // lazily on the first fault event, so fault-free digests are unchanged.
    let clock = Arc::new(VirtualClock::new());
    let mut obs = Obs::with_clock(
        Arc::clone(&clock) as Arc<dyn crate::obs::Clock>,
        CLUSTER_TRACE_SAMPLE,
        CLUSTER_RECORDER_CAP,
        cfg.trace,
    );

    let mut end_ns = 0u64;
    while let Some((now, ev)) = evq.pop() {
        clock.set(now);
        match ev {
            Event::Arrival { idx } => {
                if idx + 1 < arrivals.len() {
                    // Clamp: validated traces are monotone, but never let
                    // virtual time run backwards.
                    evq.push(arrivals[idx + 1].at_ns.max(now), Event::Arrival { idx: idx + 1 });
                }
                let a = &arrivals[idx];
                let s = router.route(a.kind, a.n, a.signals, &shards);
                shards[s].enqueue(SimRequest {
                    id: idx as u64,
                    kind: a.kind,
                    n: a.n,
                    signals: a.signals,
                    arrive_ns: now,
                });
                if !shards[s].is_busy() {
                    let started = fill_slots(&mut shards, s, now, cfg.window_signals, &mut evq)?;
                    if !started && !shards[s].deadline_scheduled {
                        shards[s].deadline_scheduled = true;
                        evq.push(now + wait_ns, Event::Deadline { shard: s });
                    }
                }
            }
            Event::Deadline { shard: s } => {
                shards[s].deadline_scheduled = false;
                if !shards[s].is_busy() {
                    fill_slots(&mut shards, s, now, 1, &mut evq)?;
                }
            }
            Event::Complete { shard: s, slot, epoch } => {
                if !shards[s].completes(slot, epoch) {
                    // Raced a crash: the batch was aborted and its requests
                    // already requeued or failed.
                    continue;
                }
                // Completions — not stale deadlines popping after the last
                // batch — define the makespan (and thus utilization).
                end_ns = end_ns.max(now);
                let f = shards[s].finish_batch(slot);
                obs.registry.inc("cluster_batches_total");
                // Feedback for learning routers: straggler-scaled observed
                // time per padded signal on this shard's device class.
                router.observe(
                    f.kind,
                    f.n,
                    shards[s].spec().class.name(),
                    f.service_ns as f64 / f.padded.max(1) as f64,
                );
                for req in &f.requests {
                    let latency_ns = now.saturating_sub(req.arrive_ns);
                    latency.record(latency_ns);
                    obs.registry.observe("cluster_latency_ns", latency_ns);
                    obs.registry
                        .inc_with("cluster_requests_total", &[("kind", req.kind.name())]);
                    obs.registry.add("cluster_signals_total", req.signals as u64);
                    if obs.sampled(req.id) {
                        let spans = sim_spans(
                            req,
                            s,
                            now,
                            f.start_ns,
                            f.service_ns,
                            f.occupancy,
                            &f.attr,
                        );
                        for sp in &spans {
                            obs.trace.push(sp.clone());
                        }
                        obs.recorder.record(Exemplar {
                            id: req.id,
                            kind: req.kind.name(),
                            n: req.n,
                            latency_ns,
                            reason: reason::SAMPLED,
                            spans,
                        });
                    }
                }
                // Work-conserving: serve whatever accumulated while busy.
                fill_slots(&mut shards, s, now, 1, &mut evq)?;
            }
            Event::Crash { shard: s } => {
                failures.crashes += 1;
                obs.registry.inc("cluster_crashes_total");
                shards[s].down = true;
                // Abort in-flight batches (bumping the epoch turns their
                // scheduled `Complete`s stale); queued-but-undispatched
                // requests stay on the shard's durable queue for restart.
                for req in shards[s].abort_in_flight() {
                    match crash_mode {
                        CrashMode::Requeue => {
                            failures.requeued += 1;
                            obs.registry.inc("cluster_requeued_total");
                            // Original arrive_ns kept: the wasted service
                            // lands in the request's end-to-end latency.
                            let t = router.route(req.kind, req.n, req.signals, &shards);
                            shards[t].enqueue(req);
                            if !shards[t].is_busy() {
                                let started = fill_slots(
                                    &mut shards,
                                    t,
                                    now,
                                    cfg.window_signals,
                                    &mut evq,
                                )?;
                                if !started && !shards[t].deadline_scheduled {
                                    shards[t].deadline_scheduled = true;
                                    evq.push(now + wait_ns, Event::Deadline { shard: t });
                                }
                            }
                        }
                        CrashMode::Fail => {
                            failures.failed += 1;
                            obs.registry.inc("cluster_failed_total");
                        }
                    }
                }
            }
            Event::Restart { shard: s } => {
                failures.restarts += 1;
                obs.registry.inc("cluster_restarts_total");
                shards[s].down = false;
                // Anything queued while down has waited past any window:
                // drain immediately (work-conserving, partial batches OK).
                fill_slots(&mut shards, s, now, 1, &mut evq)?;
            }
        }
    }

    let mut report = ClusterReport {
        shards: fleet.len(),
        router: cfg.router.name(),
        backend: cfg.backend.name(),
        requests: 0,
        signals: 0,
        padded_signals: 0,
        batches: 0,
        makespan_ns: end_ns,
        latency_ns: latency,
        queue_depth: LogHistogram::new(),
        occupancy_pct: LogHistogram::new(),
        movement: DataMovement::default(),
        cache_hits: 0,
        cache_misses: 0,
        per_kind: BTreeMap::new(),
        per_shard: Vec::with_capacity(fleet.len()),
        failures,
        obs_digest: obs.registry.digest(),
        obs_exemplars: obs.recorder.len() as u64,
    };
    for (i, shard) in shards.iter().enumerate() {
        let st = &shard.stats;
        let (hits, misses) = shard.cache_stats();
        for (&kind, &count) in &st.kind_requests {
            *report.per_kind.entry(kind).or_insert(0) += count;
        }
        report.requests += st.requests;
        report.signals += st.signals;
        report.padded_signals += st.padded_signals;
        report.batches += st.batches;
        report.queue_depth.merge(&st.queue_depth);
        report.occupancy_pct.merge(&st.occupancy_pct);
        report.movement.add_assign(&st.movement);
        report.cache_hits += hits;
        report.cache_misses += misses;
        if shard.service_mult() > 1.0 {
            report.failures.straggler_shards += 1;
            report.failures.straggler_busy_ns += st.busy_ns;
        }
        report.per_shard.push(ShardSummary {
            shard: i,
            class: shard.spec().class.name(),
            requests: st.requests,
            signals: st.signals,
            batches: st.batches,
            busy_ns: st.busy_ns,
            utilization: if end_ns == 0 { 0.0 } else { st.busy_ns as f64 / end_ns as f64 },
            movement: st.movement,
            cache_hits: hits,
            cache_misses: misses,
        });
    }
    // The conservation law, extended for fault injection: every submitted
    // request ends in exactly one terminal bin (served or failed).
    ensure!(
        report.requests + report.failures.failed == arrivals.len() as u64,
        "simulator lost requests: served {} + failed {} of {}",
        report.requests,
        report.failures.failed,
        arrivals.len()
    );
    ensure!(
        obs.registry.counter("cluster_requests_total") == report.requests,
        "observability drift: registry counted {} requests, report has {}",
        obs.registry.counter("cluster_requests_total"),
        report.requests
    );
    Ok((report, obs))
}

/// Span timeline for one sampled simulated request: request → queue →
/// execute (subdivided per pass) → respond, all in virtual time. Pass
/// durations are `floor(frac · execute)`, so their sum never exceeds the
/// execute span.
fn sim_spans(
    req: &SimRequest,
    shard: usize,
    now: u64,
    start_ns: u64,
    service_ns: u64,
    occupancy_pct: u64,
    passes: &[PassAttribution],
) -> Vec<SpanRecord> {
    let tid = shard as u64;
    let latency_ns = now.saturating_sub(req.arrive_ns);
    let mut spans = Vec::with_capacity(4 + passes.len());
    spans.push(SpanRecord {
        name: format!("request {}", req.id),
        cat: "request",
        ts_ns: req.arrive_ns,
        dur_ns: latency_ns,
        tid,
        args: vec![
            ("kind", Json::str(req.kind.name())),
            ("n", Json::num(req.n as f64)),
            ("signals", Json::num(req.signals as f64)),
        ],
    });
    spans.push(SpanRecord {
        name: "queue".into(),
        cat: "phase",
        ts_ns: req.arrive_ns,
        dur_ns: start_ns.saturating_sub(req.arrive_ns),
        tid,
        args: vec![],
    });
    let exec_ns = service_ns.min(now.saturating_sub(start_ns));
    spans.push(SpanRecord {
        name: "execute".into(),
        cat: "phase",
        ts_ns: start_ns,
        dur_ns: exec_ns,
        tid,
        args: vec![("occupancy_pct", Json::num(occupancy_pct as f64))],
    });
    let mut t = start_ns;
    for p in passes {
        let dur = (p.frac * exec_ns as f64).floor() as u64;
        spans.push(SpanRecord {
            name: format!("pass:{}", p.label),
            cat: "pass",
            ts_ns: t,
            dur_ns: dur,
            tid,
            args: vec![
                ("substrate", Json::str(p.substrate)),
                ("fft_n", Json::num(p.fft_n as f64)),
                ("ffts", Json::num(p.ffts as f64)),
                ("gpu_mb", Json::num(p.gpu_bytes / 1e6)),
                ("pim_cmd_mb", Json::num(p.pim_cmd_bytes / 1e6)),
                ("pim_tile", Json::num(p.pim_tile as f64)),
            ],
        });
        t += dur;
    }
    spans.push(SpanRecord {
        name: "respond".into(),
        cat: "phase",
        ts_ns: now,
        dur_ns: 0,
        tid,
        args: vec![],
    });
    spans
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::parse_fleet;
    use crate::coordinator::{Arrival, SizeMix, Workload};

    fn trace(requests: usize, rps: f64, sizes: &[usize], seed: u64) -> Trace {
        Workload::new(Arrival::Poisson, rps, SizeMix::uniform(sizes).unwrap())
            .unwrap()
            .generate(requests, seed)
    }

    #[test]
    fn serves_every_request() {
        let t = trace(500, 200_000.0, &[32, 4096, 8192], 7);
        let mut cfg = ClusterConfig::default_hw();
        cfg.shards = 3;
        let rep = run_cluster(&t, &cfg).unwrap();
        assert_eq!(rep.requests, 500);
        assert_eq!(rep.latency_ns.count(), 500);
        assert!(rep.signals >= 500); // every request has ≥1 signal
        assert!(rep.padded_signals >= rep.signals);
        assert!(rep.batches > 0 && rep.batches <= 500);
        assert!(rep.makespan_ns > 0);
        assert!(rep.movement.total() > 0.0);
        assert!(rep.latency_p_us(50.0) <= rep.latency_p_us(99.0));
        let served: u64 = rep.per_shard.iter().map(|s| s.requests).sum();
        assert_eq!(served, 500);
        for s in &rep.per_shard {
            assert!(s.utilization >= 0.0 && s.utilization <= 1.0);
            assert_eq!(s.class, "mixed");
        }
        assert_eq!(rep.failures, FailureSummary::default());
    }

    #[test]
    fn single_batch_latency_is_wait_plus_service() {
        // One lone request: it waits out the full batching window on an
        // idle shard, then serves alone.
        let t = Trace {
            entries: vec![crate::coordinator::TraceEntry {
                at_us: 10.0,
                kind: WorkloadKind::Batch1d,
                n: 64,
                batch: 1,
                seed: 1,
                deadline_us: None,
            }],
        };
        let mut cfg = ClusterConfig::default_hw();
        cfg.shards = 1;
        cfg.max_wait_us = 50.0;
        let rep = run_cluster(&t, &cfg).unwrap();
        assert_eq!(rep.requests, 1);
        let lat_us = rep.latency_ns.max() as f64 / 1e3;
        assert!(lat_us >= 50.0, "latency {lat_us} must include the 50µs window");
        assert!(lat_us < 60.0, "latency {lat_us} should be window + tiny service");
    }

    #[test]
    fn threaded_run_is_byte_identical_and_fully_warmed() {
        let t = trace(400, 300_000.0, &[64, 4096, 16384], 5);
        let mut cfg = ClusterConfig::default_hw();
        cfg.shards = 3;
        let want = run_cluster(&t, &cfg).unwrap().to_json().to_string();
        cfg.threads = crate::runtime::Parallelism::Fixed(2);
        let got = run_cluster(&t, &cfg).unwrap().to_json().to_string();
        assert_eq!(got, want, "threads must not change the report");
        // The warm table covers every shape the run dispatched: identical
        // hit/miss counters prove no shard fell back to a cold planner run
        // with different timing but also that stats stayed untouched.
        let warm = warm_plans(&t, &cfg).unwrap();
        assert!(!warm.is_empty());
    }

    #[test]
    fn tracing_does_not_perturb_the_report() {
        let t = trace(300, 250_000.0, &[64, 8192], 9);
        let mut cfg = ClusterConfig::default_hw();
        cfg.shards = 2;
        let (plain, obs_off) = run_cluster_traced(&t, &cfg).unwrap();
        cfg.trace = true;
        let (traced, obs_on) = run_cluster_traced(&t, &cfg).unwrap();
        // Bit-identical reports — tracing only fills the span buffer.
        assert_eq!(plain.to_json().to_string(), traced.to_json().to_string());
        assert!(obs_off.trace.is_empty());
        assert!(!obs_on.trace.is_empty());
        // The fixed 1-in-64 sampling policy retained exemplars either way.
        assert_eq!(obs_off.recorder.len(), obs_on.recorder.len());
        assert!(plain.obs_exemplars > 0);
        assert_eq!(plain.obs_digest.len(), 16);
        // Registry agrees with the report's own accounting.
        assert_eq!(obs_on.registry.counter("cluster_requests_total"), plain.requests);
        assert_eq!(obs_on.registry.counter("cluster_signals_total"), plain.signals);
        // Virtual-time spans: every sampled request's pass spans fit inside
        // its execute span.
        for ex in obs_on.recorder.iter() {
            let exec = ex.spans.iter().find(|s| s.name == "execute").unwrap();
            let pass_sum: u64 =
                ex.spans.iter().filter(|s| s.cat == "pass").map(|s| s.dur_ns).sum();
            assert!(pass_sum <= exec.dur_ns, "pass sum {pass_sum} > exec {}", exec.dur_ns);
        }
    }

    #[test]
    fn rejects_bad_configs() {
        let t = trace(10, 100_000.0, &[64], 1);
        let mut cfg = ClusterConfig::default_hw();
        cfg.shards = 0;
        assert!(run_cluster(&t, &cfg).is_err());
        let mut cfg = ClusterConfig::default_hw();
        cfg.window_signals = 0;
        assert!(run_cluster(&t, &cfg).is_err());
        let mut cfg = ClusterConfig::default_hw();
        cfg.fleet = vec![ShardSpec { threads: 0, ..ShardSpec::mixed() }];
        assert!(run_cluster(&t, &cfg).is_err());
        let mut cfg = ClusterConfig::default_hw();
        cfg.faults = Some(FaultPlan { restart_after_us: 0.0, ..FaultPlan::default() });
        assert!(run_cluster(&t, &cfg).is_err());
        let cfg = ClusterConfig::default_hw();
        assert!(run_cluster(&Trace::default(), &cfg).is_err());
    }

    #[test]
    fn more_shards_never_raise_served_latency_much() {
        // Sanity, not a theorem: on an overloaded single shard the tail is
        // far worse than on eight shards.
        // Round-robin: a single-size trace must actually spread (affinity
        // would pin everything to one shard no matter the count).
        let t = trace(2000, 2_000_000.0, &[16384], 11);
        let mut one = ClusterConfig::default_hw();
        one.router = RouterKind::RoundRobin;
        one.shards = 1;
        let mut eight = one.clone();
        eight.shards = 8;
        let r1 = run_cluster(&t, &one).unwrap();
        let r8 = run_cluster(&t, &eight).unwrap();
        assert!(
            r1.latency_p_us(99.0) > r8.latency_p_us(99.0),
            "1-shard p99 {} should exceed 8-shard p99 {}",
            r1.latency_p_us(99.0),
            r8.latency_p_us(99.0)
        );
    }

    #[test]
    fn empty_fleet_matches_homogeneous_shards() {
        // The tentpole's compatibility contract: an explicit all-baseline
        // fleet is bit-identical to the historical `shards = N` config.
        let t = trace(300, 250_000.0, &[64, 8192], 3);
        let mut legacy = ClusterConfig::default_hw();
        legacy.shards = 3;
        let mut fleet = ClusterConfig::default_hw();
        fleet.fleet = vec![ShardSpec::mixed(); 3];
        fleet.shards = 999; // must be ignored when a fleet is set
        let a = run_cluster(&t, &legacy).unwrap().to_json().to_string();
        let b = run_cluster(&t, &fleet).unwrap().to_json().to_string();
        assert_eq!(a, b);
    }

    #[test]
    fn heterogeneous_fleet_serves_and_labels_classes() {
        let t = trace(400, 300_000.0, &[4096, 16384], 13);
        let mut cfg = ClusterConfig::default_hw();
        cfg.fleet = parse_fleet("gpu:1,pim:1,mixed:1").unwrap();
        cfg.router = RouterKind::CostAware;
        let rep = run_cluster(&t, &cfg).unwrap();
        assert_eq!(rep.requests, 400);
        assert_eq!(rep.shards, 3);
        let classes: Vec<&str> = rep.per_shard.iter().map(|s| s.class).collect();
        assert_eq!(classes, vec!["gpu-only", "pim-heavy", "mixed"]);
        // The GPU-only shard moves no PIM command traffic.
        assert_eq!(rep.per_shard[0].movement.pim_cmd_bytes, 0.0);
    }

    #[test]
    fn heterogeneous_threaded_run_is_byte_identical() {
        let t = trace(400, 400_000.0, &[4096, 16384], 21);
        let mut cfg = ClusterConfig::default_hw();
        cfg.fleet = parse_fleet("gpu:2,pim:2").unwrap();
        cfg.router = RouterKind::CostAware;
        cfg.faults = Some(FaultPlan::parse("mtbf=3000,down=500,straggler=0.5:3,seed=9").unwrap());
        let want = run_cluster(&t, &cfg).unwrap().to_json().to_string();
        cfg.threads = crate::runtime::Parallelism::Fixed(2);
        let got = run_cluster(&t, &cfg).unwrap().to_json().to_string();
        assert_eq!(got, want, "fleet + faults must stay thread-count invariant");
    }

    #[test]
    fn crashes_requeue_and_conserve() {
        let t = trace(600, 500_000.0, &[4096, 8192], 17);
        let mut cfg = ClusterConfig::default_hw();
        cfg.shards = 3;
        cfg.faults = Some(FaultPlan::parse("mtbf=500,down=200,mode=requeue,seed=4").unwrap());
        let rep = run_cluster(&t, &cfg).unwrap();
        // Requeue mode: nothing is lost, every submitted request serves.
        assert_eq!(rep.requests, 600);
        assert_eq!(rep.failures.failed, 0);
        assert!(rep.failures.crashes > 0, "500µs MTBF must crash: {:?}", rep.failures);
        assert!(rep.failures.restarts > 0);
        assert!(rep.failures.requeued > 0, "crashes must catch batches mid-flight");
    }

    #[test]
    fn crashes_fail_mode_accounts_losses() {
        let t = trace(600, 500_000.0, &[4096, 8192], 17);
        let mut cfg = ClusterConfig::default_hw();
        cfg.shards = 3;
        cfg.faults = Some(FaultPlan::parse("mtbf=500,down=200,mode=fail,seed=4").unwrap());
        let rep = run_cluster(&t, &cfg).unwrap();
        assert!(rep.failures.failed > 0, "fail mode must lose in-flight requests");
        assert_eq!(rep.requests + rep.failures.failed, 600, "conservation with losses");
        assert_eq!(rep.failures.requeued, 0);
        assert_eq!(rep.latency_ns.count(), rep.requests);
    }

    #[test]
    fn stragglers_slow_the_tail_and_are_reported() {
        let t = trace(500, 400_000.0, &[8192], 19);
        let mut cfg = ClusterConfig::default_hw();
        cfg.shards = 4;
        cfg.router = RouterKind::RoundRobin;
        let clean = run_cluster(&t, &cfg).unwrap();
        cfg.faults = Some(FaultPlan::parse("straggler=0.5:8,seed=2").unwrap());
        let slow = run_cluster(&t, &cfg).unwrap();
        assert_eq!(slow.failures.straggler_shards, 2);
        assert!(slow.failures.straggler_busy_ns > 0);
        assert_eq!(slow.requests, 500);
        assert!(
            slow.latency_p_us(99.0) > clean.latency_p_us(99.0),
            "8× stragglers must hurt p99: {} vs {}",
            slow.latency_p_us(99.0),
            clean.latency_p_us(99.0)
        );
    }
}
