//! SLO-aware capacity planning: the smallest shard count whose simulated
//! p99 latency meets a target.
//!
//! The planner answers the ROADMAP question directly — "how many GPU+PIM
//! shards hold p99 under the SLO at this request rate?" — by running the
//! deterministic simulator at candidate shard counts: doubling until the
//! SLO is met, then bisecting down to the boundary. The returned count
//! meets the SLO and (when greater than one) the count below it does not;
//! every probe is recorded so a report can show the latency-vs-capacity
//! curve that justified the answer.

use std::collections::btree_map::Entry;
use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, ensure, Result};

use crate::coordinator::Trace;
use crate::runtime::Parallelism;
use crate::util::Json;

use super::sim::{run_cluster, warm_plans, ClusterConfig, ClusterReport};

/// One simulated capacity probe.
#[derive(Debug, Clone, Copy)]
pub struct CapacityProbe {
    pub shards: usize,
    pub p99_us: f64,
    pub meets: bool,
}

/// The planner's answer.
#[derive(Debug, Clone)]
pub struct CapacityPlan {
    /// Minimal shard count meeting the SLO.
    pub shards: usize,
    pub slo_us: f64,
    /// p99 at the chosen count.
    pub p99_us: f64,
    /// Every (shards, p99) point the search evaluated, ascending.
    pub probes: Vec<CapacityProbe>,
    /// Full simulator report at the chosen count.
    pub report: ClusterReport,
}

impl CapacityPlan {
    pub fn summary(&self) -> String {
        format!(
            "capacity: {} shards meet p99 ≤ {:.0}µs (achieved p99 {:.1}µs, {} probes)",
            self.shards,
            self.slo_us,
            self.p99_us,
            self.probes.len()
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("slo_us", Json::num(self.slo_us)),
            ("shards", Json::num(self.shards as f64)),
            ("p99_us", Json::num(self.p99_us)),
            (
                "probes",
                Json::arr(
                    self.probes
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("shards", Json::num(p.shards as f64)),
                                ("p99_us", Json::num(p.p99_us)),
                                ("meets", Json::Bool(p.meets)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("report", self.report.to_json()),
        ])
    }
}

/// Find the minimal shard count whose simulated p99 is ≤ `slo_us` on
/// `trace`, probing at most up to `max_shards`. `cfg.shards` is ignored;
/// every other knob (router, window, system) is used as given.
pub fn plan_capacity(
    trace: &Trace,
    cfg: &ClusterConfig,
    slo_us: f64,
    max_shards: usize,
) -> Result<CapacityPlan> {
    ensure!(slo_us.is_finite() && slo_us > 0.0, "SLO must be a positive latency in µs");
    ensure!(max_shards >= 1, "max shard count must be at least 1");

    // The warm plan table depends only on the trace and engine config —
    // never on the shard count — so compute it once and share it across
    // every probe instead of re-sweeping the planner per candidate.
    let mut cfg = cfg.clone();
    if cfg.warm.is_none() && cfg.threads != Parallelism::Sequential {
        cfg.warm = Some(Arc::new(warm_plans(trace, &cfg)?));
    }

    let mut cache: BTreeMap<usize, ClusterReport> = BTreeMap::new();
    let probe = |k: usize, cache: &mut BTreeMap<usize, ClusterReport>| -> Result<f64> {
        if let Entry::Vacant(slot) = cache.entry(k) {
            let mut c = cfg.clone();
            c.shards = k;
            slot.insert(run_cluster(trace, &c)?);
        }
        Ok(cache[&k].latency_p_us(99.0))
    };

    // Double until the SLO is met.
    let mut lo = 0usize; // sentinel: "zero shards" trivially fails
    let mut hi = 1usize;
    loop {
        let p99 = probe(hi, &mut cache)?;
        if p99 <= slo_us {
            break;
        }
        if hi >= max_shards {
            bail!(
                "p99 ≤ {slo_us} µs not achievable with up to {max_shards} shards \
                 (p99 at {max_shards} shards: {p99:.1} µs)"
            );
        }
        lo = hi;
        hi = (hi * 2).min(max_shards);
    }

    // Bisect the boundary: `lo` fails (or is the zero-shard sentinel),
    // `hi` meets.
    while hi - lo > 1 {
        let mid = lo + (hi - lo) / 2;
        if probe(mid, &mut cache)? <= slo_us {
            hi = mid;
        } else {
            lo = mid;
        }
    }

    let probes: Vec<CapacityProbe> = cache
        .iter()
        .map(|(&shards, rep)| {
            let p99_us = rep.latency_p_us(99.0);
            CapacityProbe { shards, p99_us, meets: p99_us <= slo_us }
        })
        .collect();
    let report = cache.remove(&hi).unwrap();
    let p99_us = report.latency_p_us(99.0);
    Ok(CapacityPlan { shards: hi, slo_us, p99_us, probes, report })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::RouterKind;
    use crate::coordinator::{Arrival, SizeMix, Workload};

    fn hot_trace() -> Trace {
        // Large FFTs arriving fast enough to overload a single shard.
        Workload::new(Arrival::Poisson, 4_000_000.0, SizeMix::uniform(&[16384]).unwrap())
            .unwrap()
            .generate(3000, 13)
    }

    /// Capacity planning needs a router that spreads a single-size workload
    /// (size-affinity pins one size to one shard, so extra shards would
    /// never help).
    fn spreading_cfg() -> ClusterConfig {
        let mut cfg = ClusterConfig::default_hw();
        cfg.router = RouterKind::RoundRobin;
        cfg
    }

    #[test]
    fn finds_minimal_count_meeting_slo() {
        let trace = hot_trace();
        let cfg = spreading_cfg();
        let slo_us = 150.0;
        let plan = plan_capacity(&trace, &cfg, slo_us, 64).unwrap();
        assert!(plan.p99_us <= slo_us);
        assert!(plan.shards >= 1);

        // The returned count meets the SLO...
        let mut c = cfg.clone();
        c.shards = plan.shards;
        let at = run_cluster(&trace, &c).unwrap();
        assert!(at.latency_p_us(99.0) <= slo_us, "{} shards p99 {}", plan.shards, at.latency_p_us(99.0));

        // ...and one fewer does not (the single shard is overloaded, so the
        // boundary cannot sit at 1).
        assert!(plan.shards > 1, "single shard should be overloaded in this workload");
        let mut c = cfg.clone();
        c.shards = plan.shards - 1;
        let below = run_cluster(&trace, &c).unwrap();
        assert!(
            below.latency_p_us(99.0) > slo_us,
            "{} shards p99 {} should miss the {slo_us}µs SLO",
            plan.shards - 1,
            below.latency_p_us(99.0)
        );
    }

    #[test]
    fn probes_cover_the_boundary() {
        let trace = hot_trace();
        let plan = plan_capacity(&trace, &spreading_cfg(), 150.0, 64).unwrap();
        assert!(plan.probes.iter().any(|p| p.shards == plan.shards && p.meets));
        assert!(plan.probes.iter().any(|p| p.shards == plan.shards - 1 && !p.meets));
        // JSON artifact is well-formed and self-contained.
        let j = plan.to_json().to_string();
        assert!(j.contains("\"slo_us\""));
        assert!(j.contains("\"probes\""));
        assert!(j.contains("\"per_shard\""));
    }

    #[test]
    fn unachievable_slo_is_a_contextful_error() {
        let trace = hot_trace();
        let err = plan_capacity(&trace, &spreading_cfg(), 0.001, 2).unwrap_err().to_string();
        assert!(err.contains("not achievable"), "{err}");
        assert!(plan_capacity(&trace, &spreading_cfg(), -5.0, 8).is_err());
        assert!(plan_capacity(&trace, &spreading_cfg(), 100.0, 0).is_err());
    }
}
