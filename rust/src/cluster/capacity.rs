//! SLO-aware capacity planning: the smallest shard count whose simulated
//! p99 latency meets a target.
//!
//! The planner answers the ROADMAP question directly — "how many GPU+PIM
//! shards hold p99 under the SLO at this request rate?" — by running the
//! deterministic simulator at candidate shard counts: doubling until the
//! SLO is met, then bisecting down to the boundary. The returned count
//! meets the SLO and (when greater than one) the count below it does not;
//! every probe is recorded so a report can show the latency-vs-capacity
//! curve that justified the answer.
//!
//! [`plan_fleet`] generalizes the search to heterogeneous fleets: instead
//! of one homogeneous count it searches a small set of fleet *shapes* (mix
//! profiles of GPU-only / PIM-heavy / mixed shards), finds each profile's
//! minimal count the same bounded way, and picks the cheapest fleet by
//! [`ShardSpec::cost`] — answering "what's the cheapest rack mix that holds
//! the SLO", not just "how many identical nodes".

use std::collections::btree_map::Entry;
use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, ensure, Result};

use crate::coordinator::Trace;
use crate::runtime::Parallelism;
use crate::util::Json;

use super::fleet::ShardSpec;
use super::sim::{run_cluster, warm_plans, ClusterConfig, ClusterReport};

/// One simulated capacity probe.
#[derive(Debug, Clone, Copy)]
pub struct CapacityProbe {
    pub shards: usize,
    pub p99_us: f64,
    pub meets: bool,
}

/// The planner's answer.
#[derive(Debug, Clone)]
pub struct CapacityPlan {
    /// Minimal shard count meeting the SLO.
    pub shards: usize,
    pub slo_us: f64,
    /// p99 at the chosen count.
    pub p99_us: f64,
    /// Every (shards, p99) point the search evaluated, ascending.
    pub probes: Vec<CapacityProbe>,
    /// Full simulator report at the chosen count.
    pub report: ClusterReport,
}

impl CapacityPlan {
    pub fn summary(&self) -> String {
        format!(
            "capacity: {} shards meet p99 ≤ {:.0}µs (achieved p99 {:.1}µs, {} probes)",
            self.shards,
            self.slo_us,
            self.p99_us,
            self.probes.len()
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("slo_us", Json::num(self.slo_us)),
            ("shards", Json::num(self.shards as f64)),
            ("p99_us", Json::num(self.p99_us)),
            (
                "probes",
                Json::arr(
                    self.probes
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("shards", Json::num(p.shards as f64)),
                                ("p99_us", Json::num(p.p99_us)),
                                ("meets", Json::Bool(p.meets)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("report", self.report.to_json()),
        ])
    }
}

/// Find the minimal shard count whose simulated p99 is ≤ `slo_us` on
/// `trace`, probing at most up to `max_shards`. `cfg.shards` is ignored;
/// every other knob (router, window, system) is used as given.
pub fn plan_capacity(
    trace: &Trace,
    cfg: &ClusterConfig,
    slo_us: f64,
    max_shards: usize,
) -> Result<CapacityPlan> {
    ensure!(slo_us.is_finite() && slo_us > 0.0, "SLO must be a positive latency in µs");
    ensure!(max_shards >= 1, "max shard count must be at least 1");
    ensure!(
        cfg.fleet.is_empty(),
        "plan_capacity searches a homogeneous shard count and would ignore the configured \
         fleet; use plan_fleet for heterogeneous searches"
    );

    // The warm plan table depends only on the trace and engine config —
    // never on the shard count — so compute it once and share it across
    // every probe instead of re-sweeping the planner per candidate.
    let mut cfg = cfg.clone();
    if cfg.warm.is_none() && cfg.threads != Parallelism::Sequential {
        cfg.warm = Some(Arc::new(warm_plans(trace, &cfg)?));
    }

    let mut cache: BTreeMap<usize, ClusterReport> = BTreeMap::new();
    let probe = |k: usize, cache: &mut BTreeMap<usize, ClusterReport>| -> Result<f64> {
        if let Entry::Vacant(slot) = cache.entry(k) {
            let mut c = cfg.clone();
            c.shards = k;
            slot.insert(run_cluster(trace, &c)?);
        }
        Ok(cache[&k].latency_p_us(99.0))
    };

    // Double until the SLO is met.
    let mut lo = 0usize; // sentinel: "zero shards" trivially fails
    let mut hi = 1usize;
    loop {
        let p99 = probe(hi, &mut cache)?;
        if p99 <= slo_us {
            break;
        }
        if hi >= max_shards {
            bail!(
                "p99 ≤ {slo_us} µs not achievable with up to {max_shards} shards \
                 (p99 at {max_shards} shards: {p99:.1} µs)"
            );
        }
        lo = hi;
        hi = (hi * 2).min(max_shards);
    }

    // Bisect the boundary: `lo` fails (or is the zero-shard sentinel),
    // `hi` meets.
    while hi - lo > 1 {
        let mid = lo + (hi - lo) / 2;
        if probe(mid, &mut cache)? <= slo_us {
            hi = mid;
        } else {
            lo = mid;
        }
    }

    let probes: Vec<CapacityProbe> = cache
        .iter()
        .map(|(&shards, rep)| {
            let p99_us = rep.latency_p_us(99.0);
            CapacityProbe { shards, p99_us, meets: p99_us <= slo_us }
        })
        .collect();
    let report = cache.remove(&hi).unwrap();
    let p99_us = report.latency_p_us(99.0);
    Ok(CapacityPlan { shards: hi, slo_us, p99_us, probes, report })
}

/// The fleet-shape profiles [`plan_fleet`] searches: homogeneous fleets of
/// each device class, plus an alternating GPU/PIM split. A count k
/// instantiates the profile's spec list.
const FLEET_PROFILES: &[(&str, fn(usize) -> Vec<ShardSpec>)] = &[
    ("mixed", |k| vec![ShardSpec::mixed(); k]),
    ("gpu", |k| vec![ShardSpec::gpu_only(); k]),
    ("pim", |k| vec![ShardSpec::pim_heavy(); k]),
    ("gpu+pim", |k| {
        (0..k)
            .map(|i| if i % 2 == 0 { ShardSpec::gpu_only() } else { ShardSpec::pim_heavy() })
            .collect()
    }),
];

/// One simulated fleet probe.
#[derive(Debug, Clone, Copy)]
pub struct FleetProbe {
    pub profile: &'static str,
    pub shards: usize,
    pub p99_us: f64,
    pub meets: bool,
}

/// The fleet planner's answer: the cheapest profile × count meeting the SLO.
#[derive(Debug, Clone)]
pub struct FleetPlan {
    pub profile: &'static str,
    /// The winning fleet, one spec per shard.
    pub fleet: Vec<ShardSpec>,
    pub slo_us: f64,
    /// p99 of the winning fleet.
    pub p99_us: f64,
    /// Relative fleet price ([`ShardSpec::cost`] summed) — the ranking key.
    pub cost: f64,
    /// Every (profile, shards, p99) point the search evaluated.
    pub probes: Vec<FleetProbe>,
    /// Full simulator report for the winning fleet.
    pub report: ClusterReport,
}

impl FleetPlan {
    pub fn summary(&self) -> String {
        format!(
            "fleet: {} × {} meets p99 ≤ {:.0}µs (achieved p99 {:.1}µs, cost {:.2}, {} probes)",
            self.fleet.len(),
            self.profile,
            self.slo_us,
            self.p99_us,
            self.cost,
            self.probes.len()
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("slo_us", Json::num(self.slo_us)),
            ("profile", Json::str(self.profile)),
            ("shards", Json::num(self.fleet.len() as f64)),
            ("fleet", Json::arr(self.fleet.iter().map(|s| Json::str(s.label())).collect())),
            ("p99_us", Json::num(self.p99_us)),
            ("cost", Json::num(self.cost)),
            (
                "probes",
                Json::arr(
                    self.probes
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("profile", Json::str(p.profile)),
                                ("shards", Json::num(p.shards as f64)),
                                ("p99_us", Json::num(p.p99_us)),
                                ("meets", Json::Bool(p.meets)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("report", self.report.to_json()),
        ])
    }
}

/// Search heterogeneous fleet shapes for the cheapest one whose simulated
/// p99 is ≤ `slo_us`: for each mix profile (all-mixed, all-GPU, all-PIM,
/// alternating GPU+PIM) find the minimal shard count by bounded doubling +
/// bisection, then rank the per-profile winners by fleet cost (ties: fewer
/// shards, then profile order). Profiles that cannot meet the SLO within
/// `max_shards` are skipped; if none can, the error names the SLO and each
/// profile's last probe.
pub fn plan_fleet(
    trace: &Trace,
    cfg: &ClusterConfig,
    slo_us: f64,
    max_shards: usize,
) -> Result<FleetPlan> {
    ensure!(slo_us.is_finite() && slo_us > 0.0, "SLO must be a positive latency in µs");
    ensure!(max_shards >= 1, "max shard count must be at least 1");

    // Warm the baseline-system plan table once: mixed and GPU-only shards
    // share `cfg.sys` (their specs leave it untouched), so every probe of
    // those profiles reuses it. PIM-heavy systems differ and warm per run.
    let mut cfg = cfg.clone();
    cfg.fleet.clear();
    if cfg.warm.is_none() && cfg.threads != Parallelism::Sequential {
        cfg.warm = Some(Arc::new(warm_plans(trace, &cfg)?));
    }

    let mut cache: BTreeMap<(usize, usize), ClusterReport> = BTreeMap::new();
    let probe = |pi: usize, k: usize, cache: &mut BTreeMap<(usize, usize), ClusterReport>| {
        if let Entry::Vacant(slot) = cache.entry((pi, k)) {
            let mut c = cfg.clone();
            c.fleet = FLEET_PROFILES[pi].1(k);
            slot.insert(run_cluster(trace, &c)?);
        }
        anyhow::Ok(cache[&(pi, k)].latency_p_us(99.0))
    };

    // (profile index, winning count) per profile that met the SLO, and the
    // best p99 seen at max_shards among the ones that did not.
    let mut winners: Vec<(usize, usize)> = Vec::new();
    let mut misses: Vec<String> = Vec::new();
    for (pi, (name, _)) in FLEET_PROFILES.iter().enumerate() {
        let mut lo = 0usize;
        let mut hi = 1usize;
        let capped = loop {
            let p99 = probe(pi, hi, &mut cache)?;
            if p99 <= slo_us {
                break None;
            }
            if hi >= max_shards {
                break Some(p99);
            }
            lo = hi;
            hi = (hi * 2).min(max_shards);
        };
        if let Some(p99) = capped {
            misses.push(format!("{name}: p99 {p99:.1} µs at {hi} shards"));
            continue;
        }
        while hi - lo > 1 {
            let mid = lo + (hi - lo) / 2;
            if probe(pi, mid, &mut cache)? <= slo_us {
                hi = mid;
            } else {
                lo = mid;
            }
        }
        winners.push((pi, hi));
    }

    let fleet_cost =
        |pi: usize, k: usize| FLEET_PROFILES[pi].1(k).iter().map(ShardSpec::cost).sum::<f64>();
    let Some(&(pi, k)) = winners.iter().min_by(|&&(pa, ka), &&(pb, kb)| {
        fleet_cost(pa, ka)
            .total_cmp(&fleet_cost(pb, kb))
            .then(ka.cmp(&kb))
            .then(pa.cmp(&pb))
    }) else {
        bail!(
            "no fleet profile reaches p99 ≤ {slo_us} µs within {max_shards} shards \
             (last probes: {})",
            misses.join("; ")
        );
    };

    let probes: Vec<FleetProbe> = cache
        .iter()
        .map(|(&(pi, shards), rep)| {
            let p99_us = rep.latency_p_us(99.0);
            FleetProbe { profile: FLEET_PROFILES[pi].0, shards, p99_us, meets: p99_us <= slo_us }
        })
        .collect();
    let report = cache.remove(&(pi, k)).unwrap();
    let p99_us = report.latency_p_us(99.0);
    Ok(FleetPlan {
        profile: FLEET_PROFILES[pi].0,
        fleet: FLEET_PROFILES[pi].1(k),
        slo_us,
        p99_us,
        cost: fleet_cost(pi, k),
        probes,
        report,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::RouterKind;
    use crate::coordinator::{Arrival, SizeMix, Workload};

    fn hot_trace() -> Trace {
        // Large FFTs arriving fast enough to overload a single shard.
        Workload::new(Arrival::Poisson, 4_000_000.0, SizeMix::uniform(&[16384]).unwrap())
            .unwrap()
            .generate(3000, 13)
    }

    /// Capacity planning needs a router that spreads a single-size workload
    /// (size-affinity pins one size to one shard, so extra shards would
    /// never help).
    fn spreading_cfg() -> ClusterConfig {
        let mut cfg = ClusterConfig::default_hw();
        cfg.router = RouterKind::RoundRobin;
        cfg
    }

    #[test]
    fn finds_minimal_count_meeting_slo() {
        let trace = hot_trace();
        let cfg = spreading_cfg();
        let slo_us = 150.0;
        let plan = plan_capacity(&trace, &cfg, slo_us, 64).unwrap();
        assert!(plan.p99_us <= slo_us);
        assert!(plan.shards >= 1);

        // The returned count meets the SLO...
        let mut c = cfg.clone();
        c.shards = plan.shards;
        let at = run_cluster(&trace, &c).unwrap();
        assert!(at.latency_p_us(99.0) <= slo_us, "{} shards p99 {}", plan.shards, at.latency_p_us(99.0));

        // ...and one fewer does not (the single shard is overloaded, so the
        // boundary cannot sit at 1).
        assert!(plan.shards > 1, "single shard should be overloaded in this workload");
        let mut c = cfg.clone();
        c.shards = plan.shards - 1;
        let below = run_cluster(&trace, &c).unwrap();
        assert!(
            below.latency_p_us(99.0) > slo_us,
            "{} shards p99 {} should miss the {slo_us}µs SLO",
            plan.shards - 1,
            below.latency_p_us(99.0)
        );
    }

    #[test]
    fn probes_cover_the_boundary() {
        let trace = hot_trace();
        let plan = plan_capacity(&trace, &spreading_cfg(), 150.0, 64).unwrap();
        assert!(plan.probes.iter().any(|p| p.shards == plan.shards && p.meets));
        assert!(plan.probes.iter().any(|p| p.shards == plan.shards - 1 && !p.meets));
        // JSON artifact is well-formed and self-contained.
        let j = plan.to_json().to_string();
        assert!(j.contains("\"slo_us\""));
        assert!(j.contains("\"probes\""));
        assert!(j.contains("\"per_shard\""));
    }

    #[test]
    fn unachievable_slo_is_a_contextful_error() {
        let trace = hot_trace();
        let err = plan_capacity(&trace, &spreading_cfg(), 0.001, 2).unwrap_err().to_string();
        assert!(err.contains("not achievable"), "{err}");
        assert!(err.contains("2 shards"), "error must name the search bound: {err}");
        assert!(plan_capacity(&trace, &spreading_cfg(), -5.0, 8).is_err());
        assert!(plan_capacity(&trace, &spreading_cfg(), 100.0, 0).is_err());
    }

    #[test]
    fn plan_capacity_refuses_a_heterogeneous_fleet() {
        let trace = hot_trace();
        let mut cfg = spreading_cfg();
        cfg.fleet = vec![crate::cluster::ShardSpec::gpu_only()];
        let err = plan_capacity(&trace, &cfg, 150.0, 8).unwrap_err().to_string();
        assert!(err.contains("plan_fleet"), "{err}");
    }

    #[test]
    fn fleet_search_finds_a_meeting_fleet() {
        let trace = hot_trace();
        let cfg = spreading_cfg();
        let slo_us = 150.0;
        let plan = plan_fleet(&trace, &cfg, slo_us, 64).unwrap();
        assert!(plan.p99_us <= slo_us);
        assert_eq!(plan.fleet.len(), plan.report.shards);
        assert!(plan.cost > 0.0);
        // The winner really meets the SLO when re-simulated.
        let mut c = cfg.clone();
        c.fleet = plan.fleet.clone();
        let rerun = run_cluster(&trace, &c).unwrap();
        assert!(rerun.latency_p_us(99.0) <= slo_us);
        // Probes cover more than one profile (the search really compared
        // shapes), and the JSON artifact is self-contained.
        let profiles: std::collections::BTreeSet<&str> =
            plan.probes.iter().map(|p| p.profile).collect();
        assert!(profiles.len() > 1, "{profiles:?}");
        let j = plan.to_json().to_string();
        assert!(j.contains("\"profile\""));
        assert!(j.contains("\"fleet\""));
        assert!(j.contains("\"failures\""));
    }

    #[test]
    fn fleet_search_unachievable_slo_names_every_profile() {
        let trace = hot_trace();
        let err = plan_fleet(&trace, &spreading_cfg(), 0.001, 2).unwrap_err().to_string();
        assert!(err.contains("no fleet profile"), "{err}");
        assert!(err.contains("0.001"), "error must name the SLO: {err}");
        for profile in ["mixed", "gpu", "pim", "gpu+pim"] {
            assert!(err.contains(profile), "error must name profile {profile}: {err}");
        }
        assert!(plan_fleet(&trace, &spreading_cfg(), -5.0, 8).is_err());
        assert!(plan_fleet(&trace, &spreading_cfg(), 100.0, 0).is_err());
    }
}
