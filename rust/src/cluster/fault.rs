//! Seeded fault injection for the cluster simulator: shard crash/restart
//! schedules and slow-node (straggler) multipliers.
//!
//! Everything here is decided *before* virtual time starts, from a
//! dedicated RNG stream over the fault seed and the trace horizon: the
//! crash/restart timeline per shard and the straggler assignment are pure
//! functions of `(plan, shard count, horizon)`. The event core then merely
//! replays the schedule, so fault runs stay deterministic per seed and
//! byte-identical across `--threads` — exactly like the fault-free path.
//!
//! Accounting contract (enforced by `run_cluster`'s conservation ensure):
//! a crash aborts the victim shard's in-flight batches; each aborted
//! request is either **requeued** (re-routed, keeping its original arrival
//! time, so the wasted service shows up in its latency) or **failed**
//! (leaves the system through the report's `failures.failed` bin). Either
//! way `served + failed == submitted` holds.
//!
//! CLI grammar (`cluster --faults SPEC`): comma list of `key=value` over
//! `mtbf` (mean µs between crashes per shard; 0 disables crashes), `down`
//! (restart delay µs), `mode` (`requeue` | `fail`), `straggler` (`FRAC:MULT`
//! — deterministic fraction of shards serving MULT× slower), and `seed`.
//! Example: `--faults mtbf=20000,down=2000,straggler=0.25:3,mode=requeue,seed=5`.

use anyhow::{bail, ensure, Result};

use crate::util::{Json, Rng};

/// What happens to a crashed shard's in-flight requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CrashMode {
    /// Re-route aborted requests through the router (original arrival time
    /// kept, so the retry cost lands in their latency).
    Requeue,
    /// Aborted requests leave the system via the `failures.failed` bin.
    Fail,
}

impl CrashMode {
    pub fn name(self) -> &'static str {
        match self {
            CrashMode::Requeue => "requeue",
            CrashMode::Fail => "fail",
        }
    }
}

/// The seeded fault model for one cluster run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultPlan {
    /// Mean virtual µs between crashes per shard (exponential gaps);
    /// 0 disables crash injection.
    pub crash_mtbf_us: f64,
    /// Downtime between a crash and its restart, µs.
    pub restart_after_us: f64,
    pub mode: CrashMode,
    /// Fraction of shards injected as stragglers (rounded down, but at
    /// least one shard when the fraction is positive).
    pub straggler_frac: f64,
    /// Service-time multiplier on straggler shards.
    pub straggler_mult: f64,
    /// Fault-stream seed: independent of the workload seed, so the same
    /// trace can replay under many fault timelines.
    pub seed: u64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        Self {
            crash_mtbf_us: 0.0,
            restart_after_us: 1_000.0,
            mode: CrashMode::Requeue,
            straggler_frac: 0.0,
            straggler_mult: 1.0,
            seed: 1,
        }
    }
}

impl FaultPlan {
    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.crash_mtbf_us.is_finite() && self.crash_mtbf_us >= 0.0,
            "crash MTBF must be finite and non-negative, got {}",
            self.crash_mtbf_us
        );
        ensure!(
            self.restart_after_us.is_finite() && self.restart_after_us > 0.0,
            "restart delay must be a positive duration in µs, got {}",
            self.restart_after_us
        );
        ensure!(
            (0.0..=1.0).contains(&self.straggler_frac),
            "straggler fraction must be in [0, 1], got {}",
            self.straggler_frac
        );
        ensure!(
            self.straggler_mult.is_finite() && self.straggler_mult >= 1.0,
            "straggler multiplier must be ≥ 1, got {}",
            self.straggler_mult
        );
        Ok(())
    }

    /// Parse a `--faults SPEC` (see the module docs for the grammar).
    pub fn parse(spec: &str) -> Result<Self> {
        let mut plan = Self::default();
        for term in spec.split(',') {
            let term = term.trim();
            ensure!(!term.is_empty(), "empty term in faults spec '{spec}'");
            let Some((key, val)) = term.split_once('=') else {
                bail!("fault term '{term}' is not key=value (mtbf|down|mode|straggler|seed)");
            };
            match key {
                "mtbf" => {
                    plan.crash_mtbf_us = val
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad µs value in '{term}'"))?;
                }
                "down" => {
                    plan.restart_after_us = val
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad µs value in '{term}'"))?;
                }
                "seed" => {
                    plan.seed =
                        val.parse().map_err(|_| anyhow::anyhow!("bad seed in '{term}'"))?;
                }
                "mode" => {
                    plan.mode = match val {
                        "requeue" => CrashMode::Requeue,
                        "fail" => CrashMode::Fail,
                        other => bail!("unknown crash mode '{other}' (requeue|fail)"),
                    };
                }
                "straggler" => {
                    let Some((frac, mult)) = val.split_once(':') else {
                        bail!("straggler term must be FRAC:MULT, got '{term}'");
                    };
                    plan.straggler_frac = frac
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad straggler fraction in '{term}'"))?;
                    plan.straggler_mult = mult
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad straggler multiplier in '{term}'"))?;
                }
                other => bail!("unknown fault key '{other}' (mtbf|down|mode|straggler|seed)"),
            }
        }
        plan.validate()?;
        Ok(plan)
    }

    /// Per-shard crash/restart timeline over `[0, horizon_ns]`, decided
    /// entirely up front: alternating exponential up-gaps and fixed
    /// downtimes, so intervals never overlap. Returns `(at_ns, shard,
    /// is_restart)` triples in shard-major order; the event queue's FIFO
    /// tie-break makes the replay order deterministic.
    pub fn crash_schedule(&self, shards: usize, horizon_ns: u64) -> Vec<(u64, usize, bool)> {
        if self.crash_mtbf_us <= 0.0 {
            return Vec::new();
        }
        let mtbf_ns = self.crash_mtbf_us * 1e3;
        let down_ns = (self.restart_after_us * 1e3).round().max(1.0) as u64;
        let mut schedule = Vec::new();
        for shard in 0..shards {
            // One independent stream per shard: shard count changes never
            // reshuffle another shard's timeline.
            let stream = 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(shard as u64 + 1);
            let mut rng = Rng::new(self.seed ^ stream);
            let mut t = 0u64;
            loop {
                let up = rng.exp(mtbf_ns).round().max(1.0) as u64;
                t = t.saturating_add(up);
                if t > horizon_ns {
                    break;
                }
                schedule.push((t, shard, false));
                t = t.saturating_add(down_ns);
                schedule.push((t, shard, true));
            }
        }
        schedule
    }

    /// Deterministic straggler pick: `floor(frac · shards)` shards (at
    /// least one when the fraction is positive), chosen by a seeded
    /// Fisher–Yates prefix so the same seed always slows the same shards.
    pub fn straggler_multipliers(&self, shards: usize) -> Vec<f64> {
        let mut mult = vec![1.0; shards];
        if self.straggler_frac <= 0.0 || self.straggler_mult <= 1.0 {
            return mult;
        }
        let count = ((self.straggler_frac * shards as f64).floor() as usize).clamp(1, shards);
        let mut rng = Rng::new(self.seed.wrapping_mul(0xA24B_AED4_963E_E407).wrapping_add(1));
        let mut idx: Vec<usize> = (0..shards).collect();
        for i in 0..count {
            let j = rng.range(i, shards);
            idx.swap(i, j);
        }
        for &s in &idx[..count] {
            mult[s] = self.straggler_mult;
        }
        mult
    }
}

/// Failure accounting for one run: the report's `failures` section. The
/// conservation law extends to `served + failed == submitted`; requeues and
/// straggler exposure are informational (requeued requests still end in a
/// terminal bin).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FailureSummary {
    /// Crash events injected (each aborts the victim's in-flight batches).
    pub crashes: u64,
    /// Restart events that brought a shard back.
    pub restarts: u64,
    /// Requests re-routed after their shard crashed mid-batch.
    pub requeued: u64,
    /// Requests lost to crashes (`mode=fail`): the non-served terminal bin.
    pub failed: u64,
    /// Shards injected as stragglers.
    pub straggler_shards: u64,
    /// Virtual busy time accumulated on straggler shards, ns — the run's
    /// straggler exposure.
    pub straggler_busy_ns: u64,
}

impl FailureSummary {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("crashes", Json::num(self.crashes as f64)),
            ("restarts", Json::num(self.restarts as f64)),
            ("requeued", Json::num(self.requeued as f64)),
            ("failed", Json::num(self.failed as f64)),
            ("straggler_shards", Json::num(self.straggler_shards as f64)),
            ("straggler_busy_us", Json::num(self.straggler_busy_ns as f64 / 1e3)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_spec() {
        let p = FaultPlan::parse("mtbf=20000,down=2000,straggler=0.25:3,mode=fail,seed=5").unwrap();
        assert_eq!(p.crash_mtbf_us, 20_000.0);
        assert_eq!(p.restart_after_us, 2_000.0);
        assert_eq!(p.mode, CrashMode::Fail);
        assert_eq!(p.straggler_frac, 0.25);
        assert_eq!(p.straggler_mult, 3.0);
        assert_eq!(p.seed, 5);
    }

    #[test]
    fn parse_rejects_nonsense() {
        assert!(FaultPlan::parse("mtbf").is_err());
        assert!(FaultPlan::parse("mtbf=-3").is_err());
        assert!(FaultPlan::parse("down=0").is_err());
        assert!(FaultPlan::parse("mode=explode").is_err());
        assert!(FaultPlan::parse("straggler=2:3").is_err());
        assert!(FaultPlan::parse("straggler=0.5:0.5").is_err());
        assert!(FaultPlan::parse("blast=9").is_err());
        assert!(FaultPlan::parse("").is_err());
    }

    #[test]
    fn schedule_is_deterministic_and_alternating() {
        let p = FaultPlan::parse("mtbf=5000,down=500,seed=3").unwrap();
        let a = p.crash_schedule(4, 200_000_000);
        let b = p.crash_schedule(4, 200_000_000);
        assert_eq!(a, b);
        assert!(!a.is_empty(), "200ms horizon at 5ms MTBF must crash");
        for shard in 0..4 {
            let mine: Vec<_> = a.iter().filter(|&&(_, s, _)| s == shard).collect();
            for pair in mine.chunks(2) {
                assert!(!pair[0].2, "crash first");
                if let Some(r) = pair.get(1) {
                    assert!(r.2, "then restart");
                    assert_eq!(r.0 - pair[0].0, 500_000, "fixed downtime");
                }
            }
        }
    }

    #[test]
    fn schedule_is_stable_per_shard_across_fleet_sizes() {
        let p = FaultPlan::parse("mtbf=5000,down=500,seed=3").unwrap();
        let small = p.crash_schedule(2, 100_000_000);
        let big = p.crash_schedule(6, 100_000_000);
        let shard0 = |v: &[(u64, usize, bool)]| -> Vec<(u64, bool)> {
            v.iter().filter(|&&(_, s, _)| s == 0).map(|&(t, _, r)| (t, r)).collect()
        };
        assert_eq!(shard0(&small), shard0(&big));
    }

    #[test]
    fn no_mtbf_means_no_schedule() {
        assert!(FaultPlan::default().crash_schedule(8, u64::MAX).is_empty());
    }

    #[test]
    fn stragglers_are_seeded_and_bounded() {
        let p = FaultPlan::parse("straggler=0.5:4,seed=9").unwrap();
        let m = p.straggler_multipliers(8);
        assert_eq!(m.iter().filter(|&&x| x == 4.0).count(), 4);
        assert_eq!(m, p.straggler_multipliers(8));
        // A positive fraction always slows at least one shard.
        let tiny = FaultPlan::parse("straggler=0.01:2,seed=9").unwrap();
        assert_eq!(tiny.straggler_multipliers(4).iter().filter(|&&x| x > 1.0).count(), 1);
        assert!(FaultPlan::default().straggler_multipliers(4).iter().all(|&x| x == 1.0));
    }
}
