//! The discrete-event core: a virtual clock driven by a deterministic
//! min-heap of timestamped events.
//!
//! Determinism is the whole point — capacity answers must be reproducible —
//! so ties in virtual time are broken by an insertion sequence number
//! (FIFO), never by heap internals. Same trace + same config ⇒ the exact
//! same event interleaving, bit for bit.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// One simulator event. Ordered only so it can sit inside the heap tuple;
/// (time, seq) always decides first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Event {
    /// Trace entry `idx` arrives at the cluster front door.
    Arrival { idx: usize },
    /// Shard `shard`'s batching window expired: serve a partial batch.
    Deadline { shard: usize },
    /// Shard `shard` finishes the batch in slot `slot`. `epoch` is the
    /// shard's crash epoch at dispatch time: a completion whose epoch no
    /// longer matches raced a crash and is ignored (the batch was already
    /// aborted and its requests requeued or failed).
    Complete { shard: usize, slot: usize, epoch: u64 },
    /// Fault injection: shard `shard` crashes, aborting its in-flight
    /// batches (scheduled up front by `FaultPlan::crash_schedule`).
    Crash { shard: usize },
    /// Fault injection: shard `shard` comes back after its downtime.
    Restart { shard: usize },
}

/// Min-heap of `(virtual time ns, seq, event)`.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<(u64, u64, Event)>>,
    seq: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, at_ns: u64, ev: Event) {
        self.heap.push(Reverse((at_ns, self.seq, ev)));
        self.seq += 1;
    }

    /// Pop the earliest event (FIFO among equal timestamps).
    pub fn pop(&mut self) -> Option<(u64, Event)> {
        self.heap.pop().map(|Reverse((t, _, ev))| (t, ev))
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order_fifo_on_ties() {
        let mut q = EventQueue::new();
        q.push(30, Event::Complete { shard: 0, slot: 0, epoch: 0 });
        q.push(10, Event::Arrival { idx: 1 });
        q.push(10, Event::Deadline { shard: 2 });
        q.push(20, Event::Arrival { idx: 0 });
        q.push(10, Event::Crash { shard: 1 });
        assert_eq!(q.len(), 5);
        assert_eq!(q.pop(), Some((10, Event::Arrival { idx: 1 })));
        assert_eq!(q.pop(), Some((10, Event::Deadline { shard: 2 })));
        assert_eq!(q.pop(), Some((10, Event::Crash { shard: 1 })));
        assert_eq!(q.pop(), Some((20, Event::Arrival { idx: 0 })));
        assert_eq!(q.pop(), Some((30, Event::Complete { shard: 0, slot: 0, epoch: 0 })));
        assert!(q.pop().is_none());
        assert!(q.is_empty());
    }
}
