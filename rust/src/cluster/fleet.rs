//! Heterogeneous fleet description: per-shard device specs.
//!
//! A real deployment rarely fields N identical GPU+PIM nodes: some racks
//! carry plain GPUs, some carry PIM-dense HBM stacks, and Inclusive-PIM-style
//! tuning (PAPERS.md) says the right host/PIM split is per-device. A
//! [`ShardSpec`] captures one shard's hardware shape — device class, HBM
//! stack count, PIM units per stack, and concurrent batch slots — and the
//! simulator prices every batch on an engine built from exactly that spec
//! (`SystemConfig` mutation), so a mixed fleet's report reflects real
//! per-class service-time differences, not a knob.
//!
//! The CLI grammar (`cluster --fleet SPEC`) is a comma list of
//! `class[/sN][/uN][/tN][:count]` terms: `gpu:2,pim:4` is two GPU-only
//! shards plus four PIM-heavy ones; `mixed/s8/t2:2` is two mixed shards
//! with eight HBM stacks and two batch slots each. `--fleet auto` (with
//! `--slo-us`) asks the capacity planner to search fleet shapes instead.

use anyhow::{bail, ensure, Result};

use crate::config::SystemConfig;

/// What compute a shard fields. Pricing per class:
///
/// * `GpuOnly` — no PIM provisioned: batches are priced at the engine's
///   GPU-baseline time (`WorkloadEval::gpu_only_ns`, baseline movement);
/// * `PimHeavy` — one PIM unit per bank (the paper's §6.6 `pim-per-bank`
///   sensitivity point): collaborative plans with doubled PIM parallelism;
/// * `Mixed` — the paper-baseline collaborative configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum DeviceClass {
    GpuOnly,
    PimHeavy,
    Mixed,
}

impl DeviceClass {
    pub fn name(self) -> &'static str {
        match self {
            DeviceClass::GpuOnly => "gpu-only",
            DeviceClass::PimHeavy => "pim-heavy",
            DeviceClass::Mixed => "mixed",
        }
    }
}

/// One shard's hardware shape. Defaults mirror the paper baseline (4 HBM
/// stacks, 256 PIM units/stack, one batch slot), so an unspecified fleet is
/// bit-identical to the historical homogeneous simulator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    pub class: DeviceClass,
    /// HBM stacks on this shard (baseline: 4). Scales memory bandwidth and
    /// PIM parallelism in every model the engine prices with.
    pub stacks: usize,
    /// PIM units per stack (baseline: 256; `pim-per-bank`: 512). Ignored at
    /// pricing time by `GpuOnly` shards but kept valid so the `SystemConfig`
    /// geometry stays well-formed.
    pub pim_units: usize,
    /// Concurrent batch slots (host dispatch width): how many priced
    /// batches this shard serves at once in virtual time.
    pub threads: usize,
}

impl ShardSpec {
    pub fn mixed() -> Self {
        Self { class: DeviceClass::Mixed, stacks: 4, pim_units: 256, threads: 1 }
    }

    pub fn gpu_only() -> Self {
        Self { class: DeviceClass::GpuOnly, ..Self::mixed() }
    }

    pub fn pim_heavy() -> Self {
        Self { class: DeviceClass::PimHeavy, pim_units: 512, ..Self::mixed() }
    }

    /// The engine configuration this spec prices with: `base` with the
    /// spec's stack count and PIM density applied.
    pub fn system(&self, base: &SystemConfig) -> SystemConfig {
        let mut sys = base.clone();
        sys.hbm.stacks = self.stacks;
        sys.pim = sys.pim.with_units_per_stack(self.pim_units);
        if sys != *base {
            sys.name = format!("{}[{}]", base.name, self.label());
        }
        sys
    }

    /// Compact display label, also the per-shard `class` field in reports:
    /// `"pim-heavy/s4/u512/t1"`.
    pub fn label(&self) -> String {
        format!("{}/s{}/u{}/t{}", self.class.name(), self.stacks, self.pim_units, self.threads)
    }

    /// Relative fleet price of one shard of this spec (the capacity
    /// planner's ranking metric, not dollars): GPU board + HBM stacks +
    /// provisioned PIM + host dispatch width.
    pub fn cost(&self) -> f64 {
        let pim = match self.class {
            DeviceClass::GpuOnly => 0.0,
            _ => (self.pim_units as f64 / 256.0) * 0.25,
        };
        (1.0 + 0.25 * self.stacks as f64 / 4.0 + pim) * (1.0 + 0.1 * (self.threads - 1) as f64)
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(self.threads >= 1, "shard spec needs at least one batch slot");
        ensure!(self.stacks >= 1, "shard spec needs at least one HBM stack");
        ensure!(
            self.pim_units >= 1 && self.pim_units.is_power_of_two(),
            "PIM units per stack must be a positive power of two, got {}",
            self.pim_units
        );
        Ok(())
    }

    /// Parse one spec term: `class[/sN][/uN][/tN]` with classes `gpu` |
    /// `pim` | `mixed`.
    pub fn parse(term: &str) -> Result<Self> {
        let mut parts = term.split('/');
        let mut spec = match parts.next().unwrap_or("") {
            "gpu" | "gpu-only" => Self::gpu_only(),
            "pim" | "pim-heavy" => Self::pim_heavy(),
            "mixed" => Self::mixed(),
            other => bail!("unknown shard class '{other}' (gpu|pim|mixed)"),
        };
        for p in parts {
            let (key, val) = p.split_at(1.min(p.len()));
            let parsed: usize = val
                .parse()
                .map_err(|_| anyhow::anyhow!("bad shard spec attribute '{p}' in '{term}'"))?;
            match key {
                "s" => spec.stacks = parsed,
                "u" => spec.pim_units = parsed,
                "t" => spec.threads = parsed,
                _ => bail!("unknown shard spec attribute '{p}' in '{term}' (s|u|t + number)"),
            }
        }
        spec.validate()?;
        Ok(spec)
    }
}

/// Parse a full `--fleet SPEC`: comma list of `term[:count]`. Returns one
/// [`ShardSpec`] per shard, in CLI order.
pub fn parse_fleet(spec: &str) -> Result<Vec<ShardSpec>> {
    let mut fleet = Vec::new();
    for term in spec.split(',') {
        let term = term.trim();
        ensure!(!term.is_empty(), "empty term in fleet spec '{spec}'");
        let (body, count) = match term.rsplit_once(':') {
            Some((body, c)) => {
                let count: usize =
                    c.parse().map_err(|_| anyhow::anyhow!("bad shard count '{c}' in '{term}'"))?;
                ensure!(count >= 1, "shard count in '{term}' must be at least 1");
                (body, count)
            }
            None => (term, 1),
        };
        let shard = ShardSpec::parse(body)?;
        fleet.extend(std::iter::repeat(shard).take(count));
    }
    ensure!(!fleet.is_empty(), "fleet spec '{spec}' names no shards");
    Ok(fleet)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_and_labels() {
        assert_eq!(ShardSpec::mixed().label(), "mixed/s4/u256/t1");
        assert_eq!(ShardSpec::gpu_only().label(), "gpu-only/s4/u256/t1");
        assert_eq!(ShardSpec::pim_heavy().label(), "pim-heavy/s4/u512/t1");
    }

    #[test]
    fn default_spec_leaves_the_system_untouched() {
        let base = SystemConfig::baseline().with_hw_opt();
        let sys = ShardSpec::mixed().system(&base);
        assert_eq!(sys, base, "baseline spec must not perturb the engine config");
    }

    #[test]
    fn pim_heavy_doubles_units() {
        let base = SystemConfig::baseline().with_hw_opt();
        let sys = ShardSpec::pim_heavy().system(&base);
        assert_eq!(sys.pim.units_per_stack, 512);
        assert_eq!(sys.banks_per_unit(), 1);
        assert_ne!(sys.name, base.name);
    }

    #[test]
    fn parse_terms_and_counts() {
        let fleet = parse_fleet("gpu:2,pim/u512:1,mixed/s8/t2").unwrap();
        assert_eq!(fleet.len(), 4);
        assert_eq!(fleet[0], ShardSpec::gpu_only());
        assert_eq!(fleet[1], ShardSpec::gpu_only());
        assert_eq!(fleet[2], ShardSpec::pim_heavy());
        assert_eq!(fleet[3].stacks, 8);
        assert_eq!(fleet[3].threads, 2);
    }

    #[test]
    fn parse_rejects_nonsense() {
        assert!(parse_fleet("tpu:2").is_err());
        assert!(parse_fleet("gpu:0").is_err());
        assert!(parse_fleet("gpu/x9").is_err());
        assert!(parse_fleet("gpu/u3").is_err(), "non-power-of-two PIM units");
        assert!(parse_fleet("").is_err());
        assert!(parse_fleet("gpu:two").is_err());
    }

    #[test]
    fn costs_rank_classes_sensibly() {
        let gpu = ShardSpec::gpu_only().cost();
        let mixed = ShardSpec::mixed().cost();
        let pim = ShardSpec::pim_heavy().cost();
        assert!(gpu < mixed && mixed < pim, "{gpu} {mixed} {pim}");
        let wide = ShardSpec { threads: 4, ..ShardSpec::mixed() };
        assert!(wide.cost() > mixed);
    }
}
