//! One serving shard: an [`FftEngine`] behind a size-keyed queue with
//! windowed batching, concurrent batch slots, and crash/straggler hooks.
//!
//! The simulator never computes spectra — a shard serves *virtual* requests
//! whose service time is the engine's own cost estimate for the batch shape
//! (`FftEngine::plan`), exactly the numbers the paper's figures are built
//! from. Batches are padded to the next power-of-two signal count (the PJRT
//! artifacts have fixed shapes), which both prices padding waste honestly
//! and keeps the engine's plan cache keyed by a small set of shapes.
//!
//! Heterogeneity enters through the shard's [`ShardSpec`]: the engine is
//! built from the spec's mutated `SystemConfig`, `GpuOnly` shards price at
//! the GPU-baseline time instead of the collaborative plan, the spec's
//! `threads` sets how many batches serve concurrently, and a fault plan may
//! scale service times (stragglers) or abort in-flight batches (crashes).
//! Stats are committed at *completion*, so a crashed batch contributes
//! nothing to served counters — its requests are requeued or failed by the
//! simulator with separate accounting.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::backend::{FftEngine, PassAttribution};
use crate::coordinator::{Batchable, Batcher};
use crate::metrics::{DataMovement, LogHistogram};
use crate::workload::WorkloadKind;

use super::fleet::{DeviceClass, ShardSpec};

/// A queued simulated request: no signal payload, just the shape and the
/// arrival timestamp the latency accounting needs.
#[derive(Debug, Clone, Copy)]
pub struct SimRequest {
    /// Trace entry index.
    pub id: u64,
    /// Workload kind.
    pub kind: WorkloadKind,
    /// FFT size.
    pub n: usize,
    /// Signals in the request.
    pub signals: usize,
    /// Arrival time, virtual ns.
    pub arrive_ns: u64,
}

impl Batchable for SimRequest {
    fn fft_size(&self) -> usize {
        self.n
    }

    fn kind(&self) -> WorkloadKind {
        self.kind
    }

    fn signal_count(&self) -> usize {
        self.signals
    }
}

/// Counters one shard accumulates over a run.
#[derive(Debug, Clone, Default)]
pub struct ShardStats {
    /// Requests completed.
    pub requests: u64,
    /// Signals actually served (excluding padding).
    pub signals: u64,
    /// Signals after batch padding (what the substrate executes).
    pub padded_signals: u64,
    /// Batches dispatched.
    pub batches: u64,
    /// Virtual time spent serving, ns.
    pub busy_ns: u64,
    /// Modeled data movement of every executed plan, split per substrate
    /// (GPU signal bytes vs PIM command bytes).
    pub movement: DataMovement,
    /// Requests completed, by workload kind.
    pub kind_requests: BTreeMap<WorkloadKind, u64>,
    /// Queue depth (requests) sampled at every arrival.
    pub queue_depth: LogHistogram,
    /// Batch occupancy, percent of the padded shape actually used.
    pub occupancy_pct: LogHistogram,
}

/// One dispatched batch occupying a slot until its `Complete` event.
#[derive(Debug)]
pub(crate) struct InFlight {
    pub(crate) requests: Vec<SimRequest>,
    pub(crate) kind: WorkloadKind,
    pub(crate) n: usize,
    pub(crate) signals: usize,
    pub(crate) padded: usize,
    /// Virtual dispatch time.
    pub(crate) start_ns: u64,
    /// Modeled service time (straggler-scaled), ns.
    pub(crate) service_ns: u64,
    /// Occupancy (percent of the padded shape used).
    pub(crate) occupancy: u64,
    pub(crate) movement: DataMovement,
    /// Per-pass substrate/byte attribution of the batch's plan — what the
    /// simulator's span timelines subdivide execute spans with.
    pub(crate) attr: Vec<PassAttribution>,
}

/// A shard: engine + queue + the in-flight batch slots.
pub struct Shard {
    engine: FftEngine,
    spec: ShardSpec,
    /// Straggler service-time multiplier (1.0 = healthy node).
    service_mult: f64,
    pub(crate) batcher: Batcher<SimRequest>,
    pub(crate) deadline_scheduled: bool,
    /// Crashed and not yet restarted: accepts queued work, dispatches none.
    pub(crate) down: bool,
    /// Bumped on every crash, carried by `Complete` events: a completion
    /// whose epoch mismatches raced a crash and must be ignored.
    pub(crate) epoch: u64,
    slots: Vec<Option<InFlight>>,
    pub stats: ShardStats,
}

impl Shard {
    /// A paper-baseline shard (mixed class, one slot, healthy).
    pub fn new(engine: FftEngine) -> Self {
        Self::with_spec(engine, ShardSpec::mixed(), 1.0)
    }

    pub fn with_spec(engine: FftEngine, spec: ShardSpec, service_mult: f64) -> Self {
        Self {
            engine,
            spec,
            service_mult,
            batcher: Batcher::new(),
            deadline_scheduled: false,
            down: false,
            epoch: 0,
            slots: (0..spec.threads.max(1)).map(|_| None).collect(),
            stats: ShardStats::default(),
        }
    }

    pub fn spec(&self) -> &ShardSpec {
        &self.spec
    }

    /// Injected straggler multiplier (1.0 for healthy shards).
    pub fn service_mult(&self) -> f64 {
        self.service_mult
    }

    pub fn is_down(&self) -> bool {
        self.down
    }

    /// Every batch slot occupied (or the shard is down): nothing more can
    /// dispatch right now.
    pub fn is_busy(&self) -> bool {
        self.down || self.slots.iter().all(|s| s.is_some())
    }

    pub(crate) fn free_slot(&self) -> Option<usize> {
        self.slots.iter().position(|s| s.is_none())
    }

    /// Requests waiting in the queue.
    pub fn pending_requests(&self) -> usize {
        self.batcher.pending()
    }

    /// Signals waiting in the queue.
    pub fn pending_signals(&self) -> usize {
        self.batcher.pending_signals()
    }

    /// Queued + in-flight signals (the load metric routers balance on).
    pub fn load_signals(&self) -> usize {
        self.batcher.pending_signals()
            + self.slots.iter().flatten().map(|f| f.signals).sum::<usize>()
    }

    /// Plan-cache (hits, misses) of this shard's engine.
    pub fn cache_stats(&self) -> (u64, u64) {
        self.engine.cache_stats()
    }

    /// Admit a request, sampling queue depth first.
    pub(crate) fn enqueue(&mut self, req: SimRequest) {
        self.stats.queue_depth.record(self.batcher.pending() as u64);
        self.batcher.push(req);
    }

    /// Pop the next batch (round-robin across `(size, kind)` queues)
    /// holding at least `min_signals`, price it on the engine's workload
    /// decomposition per the shard's device class, and occupy a slot.
    /// Returns `(slot, modeled service ns)`, or `None` if nothing
    /// qualified or no slot (or the shard) is free.
    pub(crate) fn start_batch(
        &mut self,
        now_ns: u64,
        min_signals: usize,
    ) -> Result<Option<(usize, u64)>> {
        if self.down {
            return Ok(None);
        }
        let Some(slot) = self.free_slot() else {
            return Ok(None);
        };
        let Some(batch) = self.batcher.pop_ready(min_signals) else {
            return Ok(None);
        };
        let total = batch.total_signals();
        let padded = batch.padded_signals();
        let eval = self.engine.plan_workload(batch.kind, batch.n, padded)?;
        // Device class decides the price: a GPU-only shard executes the
        // same decomposition entirely on its GPU baseline; collaborative
        // classes serve at the planned split (whose cost already reflects
        // the spec's stack count and PIM density via the mutated system).
        let (base_ns, movement, attr) = match self.spec.class {
            DeviceClass::GpuOnly => {
                (eval.gpu_only_ns, eval.movement_base, eval.pass_attribution_gpu_only())
            }
            _ => (eval.plan_ns, eval.movement_plan, eval.pass_attribution()),
        };
        let service_ns = (base_ns.max(1.0) * self.service_mult).round() as u64;
        self.slots[slot] = Some(InFlight {
            kind: batch.kind,
            n: batch.n,
            signals: total,
            padded,
            start_ns: now_ns,
            service_ns,
            occupancy: (total * 100 / padded) as u64,
            movement,
            attr,
            requests: batch.requests,
        });
        Ok(Some((slot, service_ns)))
    }

    /// Finish the batch in `slot`, committing its stats and returning it
    /// for latency accounting. Stats commit here — not at dispatch — so an
    /// aborted (crashed) batch never pollutes served counters.
    pub(crate) fn finish_batch(&mut self, slot: usize) -> InFlight {
        let f = self.slots[slot].take().expect("finish_batch on an empty slot");
        self.stats.batches += 1;
        self.stats.signals += f.signals as u64;
        self.stats.padded_signals += f.padded as u64;
        self.stats.busy_ns += f.service_ns;
        self.stats.movement.add_assign(&f.movement);
        self.stats.occupancy_pct.record(f.occupancy);
        self.stats.requests += f.requests.len() as u64;
        for req in &f.requests {
            *self.stats.kind_requests.entry(req.kind).or_insert(0) += 1;
        }
        f
    }

    /// Crash path: drop every in-flight batch without committing stats and
    /// return the victims (slot order) for requeue/fail accounting. Bumps
    /// the epoch so already-scheduled `Complete` events turn stale.
    pub(crate) fn abort_in_flight(&mut self) -> Vec<SimRequest> {
        self.epoch += 1;
        let mut victims = Vec::new();
        for slot in &mut self.slots {
            if let Some(f) = slot.take() {
                victims.extend(f.requests);
            }
        }
        victims
    }

    /// True iff `slot` still holds the batch a `Complete { epoch }` event
    /// was scheduled for.
    pub(crate) fn completes(&self, slot: usize, epoch: u64) -> bool {
        epoch == self.epoch && self.slots[slot].is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    fn shard() -> Shard {
        let sys = SystemConfig::baseline().with_hw_opt();
        Shard::new(FftEngine::builder().system(&sys).build())
    }

    fn req1d(id: u64, n: usize, signals: usize, arrive_ns: u64) -> SimRequest {
        SimRequest { id, kind: WorkloadKind::Batch1d, n, signals, arrive_ns }
    }

    #[test]
    fn batch_lifecycle_prices_and_pads() {
        let mut s = shard();
        for id in 0..3u64 {
            s.enqueue(req1d(id, 8192, 2, id * 10));
        }
        assert_eq!(s.pending_requests(), 3);
        assert_eq!(s.pending_signals(), 6);
        assert!(!s.is_busy());
        let (slot, service) = s.start_batch(0, 1).unwrap().unwrap();
        assert!(service >= 1);
        assert!(s.is_busy(), "single-slot shard is busy while a batch is in flight");
        assert_eq!(s.pending_requests(), 0);
        assert_eq!(s.load_signals(), 6);
        // Stats commit at completion, not dispatch (a crash must be able to
        // abort without un-recording).
        assert_eq!(s.stats.batches, 0);
        let done = s.finish_batch(slot);
        assert_eq!(done.requests.len(), 3);
        assert!(!s.is_busy());
        assert_eq!(s.stats.requests, 3);
        assert_eq!(s.stats.signals, 6);
        assert_eq!(s.stats.padded_signals, 8); // 6 → padded to 8
        assert_eq!(s.stats.batches, 1);
        assert_eq!(s.stats.busy_ns, service);
        assert!(s.stats.movement.total() > 0.0);
        assert_eq!(s.load_signals(), 0);
    }

    #[test]
    fn start_batch_respects_min_signals() {
        let mut s = shard();
        s.enqueue(req1d(0, 64, 2, 0));
        assert!(s.start_batch(0, 8).unwrap().is_none());
        assert!(!s.is_busy());
        assert!(s.start_batch(0, 1).unwrap().is_some());
    }

    #[test]
    fn repeated_shapes_hit_the_plan_cache() {
        let mut s = shard();
        for round in 0..4u64 {
            s.enqueue(req1d(round, 8192, 4, 0));
            let (slot, _) = s.start_batch(0, 1).unwrap().unwrap();
            s.finish_batch(slot);
        }
        let (hits, misses) = s.cache_stats();
        assert_eq!((hits, misses), (3, 1));
    }

    #[test]
    fn kinds_are_priced_and_counted_separately() {
        let mut s = shard();
        s.enqueue(SimRequest { id: 0, kind: WorkloadKind::Batch1d, n: 8192, signals: 4, arrive_ns: 0 });
        let (slot, t1d) = s.start_batch(0, 1).unwrap().unwrap();
        s.finish_batch(slot);
        s.enqueue(SimRequest { id: 1, kind: WorkloadKind::Fft2d, n: 8192, signals: 4, arrive_ns: 0 });
        let (slot, t2d) = s.start_batch(0, 1).unwrap().unwrap();
        s.finish_batch(slot);
        // A 2D FFT of the same n runs two (smaller) passes plus transposes:
        // its modeled service time must differ from the 1D pricing.
        assert_ne!(t1d, t2d);
        assert_eq!(s.stats.kind_requests[&WorkloadKind::Batch1d], 1);
        assert_eq!(s.stats.kind_requests[&WorkloadKind::Fft2d], 1);
        // STFT decomposes into many window-size FFTs and still prices.
        s.enqueue(SimRequest { id: 2, kind: WorkloadKind::Stft, n: 8192, signals: 2, arrive_ns: 0 });
        let (slot, tstft) = s.start_batch(0, 1).unwrap().unwrap();
        assert!(tstft >= 1);
        s.finish_batch(slot);
        assert_eq!(s.stats.requests, 3);
    }

    #[test]
    fn gpu_only_spec_prices_the_baseline() {
        let sys = SystemConfig::baseline().with_hw_opt();
        let mut mixed = shard();
        let mut gpu = Shard::with_spec(
            FftEngine::builder().system(&sys).build(),
            ShardSpec::gpu_only(),
            1.0,
        );
        for s in [&mut mixed, &mut gpu] {
            s.enqueue(req1d(0, 16384, 8, 0));
        }
        let (_, plan_ns) = mixed.start_batch(0, 1).unwrap().unwrap();
        let (slot, gpu_ns) = gpu.start_batch(0, 1).unwrap().unwrap();
        // Collaborative plans beat the GPU baseline on large FFTs (the
        // paper's headline), so the GPU-only shard must price slower.
        assert!(gpu_ns > plan_ns, "gpu-only {gpu_ns} ≤ collaborative {plan_ns}");
        let f = gpu.finish_batch(slot);
        assert!(f.attr.iter().all(|a| a.substrate == "gpu-model" && a.pim_tile == 0));
        assert_eq!(f.movement.pim_cmd_bytes, 0.0);
    }

    #[test]
    fn straggler_multiplier_scales_service() {
        let sys = SystemConfig::baseline().with_hw_opt();
        let mut slow =
            Shard::with_spec(FftEngine::builder().system(&sys).build(), ShardSpec::mixed(), 4.0);
        let mut healthy = shard();
        for s in [&mut slow, &mut healthy] {
            s.enqueue(req1d(0, 8192, 4, 0));
        }
        let (_, fast_ns) = healthy.start_batch(0, 1).unwrap().unwrap();
        let (_, slow_ns) = slow.start_batch(0, 1).unwrap().unwrap();
        assert_eq!(slow_ns, (fast_ns as f64 * 4.0).round() as u64);
    }

    #[test]
    fn multi_slot_shard_serves_concurrently() {
        let sys = SystemConfig::baseline().with_hw_opt();
        let spec = ShardSpec { threads: 2, ..ShardSpec::mixed() };
        let mut s = Shard::with_spec(FftEngine::builder().system(&sys).build(), spec, 1.0);
        s.enqueue(req1d(0, 64, 1, 0));
        s.enqueue(req1d(1, 8192, 1, 0));
        let (slot_a, _) = s.start_batch(0, 1).unwrap().unwrap();
        assert!(!s.is_busy(), "second slot still free");
        let (slot_b, _) = s.start_batch(0, 1).unwrap().unwrap();
        assert_ne!(slot_a, slot_b);
        assert!(s.is_busy());
        s.finish_batch(slot_a);
        assert!(!s.is_busy());
        s.finish_batch(slot_b);
        assert_eq!(s.stats.batches, 2);
    }

    #[test]
    fn abort_returns_victims_without_stats() {
        let mut s = shard();
        for id in 0..3u64 {
            s.enqueue(req1d(id, 8192, 2, 0));
        }
        let (slot, _) = s.start_batch(0, 1).unwrap().unwrap();
        let epoch_before = s.epoch;
        assert!(s.completes(slot, epoch_before));
        let victims = s.abort_in_flight();
        assert_eq!(victims.len(), 3);
        assert_eq!(s.stats.batches, 0, "aborted batches never commit stats");
        assert_eq!(s.stats.requests, 0);
        assert!(!s.completes(slot, epoch_before), "stale completions must not fire");
        assert!(!s.is_busy());
    }

    #[test]
    fn down_shard_queues_but_does_not_dispatch() {
        let mut s = shard();
        s.down = true;
        s.enqueue(req1d(0, 64, 1, 0));
        assert!(s.start_batch(0, 1).unwrap().is_none());
        assert!(s.is_busy(), "a down shard reports busy to the dispatch loop");
        s.down = false;
        assert!(s.start_batch(0, 1).unwrap().is_some());
    }
}
