//! One serving shard: an [`FftEngine`] behind a size-keyed queue with
//! windowed batching.
//!
//! The simulator never computes spectra — a shard serves *virtual* requests
//! whose service time is the engine's own cost estimate for the batch shape
//! (`FftEngine::plan`), exactly the numbers the paper's figures are built
//! from. Batches are padded to the next power-of-two signal count (the PJRT
//! artifacts have fixed shapes), which both prices padding waste honestly
//! and keeps the engine's plan cache keyed by a small set of shapes.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::backend::{FftEngine, PassAttribution};
use crate::coordinator::{Batchable, Batcher};
use crate::metrics::{DataMovement, LogHistogram};
use crate::workload::WorkloadKind;

/// A queued simulated request: no signal payload, just the shape and the
/// arrival timestamp the latency accounting needs.
#[derive(Debug, Clone, Copy)]
pub struct SimRequest {
    /// Trace entry index.
    pub id: u64,
    /// Workload kind.
    pub kind: WorkloadKind,
    /// FFT size.
    pub n: usize,
    /// Signals in the request.
    pub signals: usize,
    /// Arrival time, virtual ns.
    pub arrive_ns: u64,
}

impl Batchable for SimRequest {
    fn fft_size(&self) -> usize {
        self.n
    }

    fn kind(&self) -> WorkloadKind {
        self.kind
    }

    fn signal_count(&self) -> usize {
        self.signals
    }
}

/// Counters one shard accumulates over a run.
#[derive(Debug, Clone, Default)]
pub struct ShardStats {
    /// Requests completed.
    pub requests: u64,
    /// Signals actually served (excluding padding).
    pub signals: u64,
    /// Signals after batch padding (what the substrate executes).
    pub padded_signals: u64,
    /// Batches dispatched.
    pub batches: u64,
    /// Virtual time spent serving, ns.
    pub busy_ns: u64,
    /// Modeled data movement of every executed plan, split per substrate
    /// (GPU signal bytes vs PIM command bytes).
    pub movement: DataMovement,
    /// Requests completed, by workload kind.
    pub kind_requests: BTreeMap<WorkloadKind, u64>,
    /// Queue depth (requests) sampled at every arrival.
    pub queue_depth: LogHistogram,
    /// Batch occupancy, percent of the padded shape actually used.
    pub occupancy_pct: LogHistogram,
}

/// A shard: engine + queue + the in-flight batch.
pub struct Shard {
    engine: FftEngine,
    pub(crate) batcher: Batcher<SimRequest>,
    pub(crate) busy: bool,
    pub(crate) deadline_scheduled: bool,
    in_flight: Vec<SimRequest>,
    in_flight_signals: usize,
    /// Virtual dispatch time of the in-flight batch (set by the sim loop).
    pub(crate) in_flight_start_ns: u64,
    /// Modeled service time of the in-flight batch, ns.
    pub(crate) in_flight_service_ns: u64,
    /// Occupancy (percent of the padded shape used) of the in-flight batch.
    pub(crate) in_flight_occupancy: u64,
    /// Per-pass substrate/byte attribution of the in-flight batch's plan —
    /// what the simulator's span timelines subdivide execute spans with.
    pub(crate) in_flight_attr: Vec<PassAttribution>,
    pub stats: ShardStats,
}

impl Shard {
    pub fn new(engine: FftEngine) -> Self {
        Self {
            engine,
            batcher: Batcher::new(),
            busy: false,
            deadline_scheduled: false,
            in_flight: Vec::new(),
            in_flight_signals: 0,
            in_flight_start_ns: 0,
            in_flight_service_ns: 0,
            in_flight_occupancy: 0,
            in_flight_attr: Vec::new(),
            stats: ShardStats::default(),
        }
    }

    pub fn is_busy(&self) -> bool {
        self.busy
    }

    /// Requests waiting in the queue.
    pub fn pending_requests(&self) -> usize {
        self.batcher.pending()
    }

    /// Signals waiting in the queue.
    pub fn pending_signals(&self) -> usize {
        self.batcher.pending_signals()
    }

    /// Queued + in-flight signals (the least-loaded router's load metric).
    pub fn load_signals(&self) -> usize {
        self.batcher.pending_signals() + self.in_flight_signals
    }

    /// Plan-cache (hits, misses) of this shard's engine.
    pub fn cache_stats(&self) -> (u64, u64) {
        self.engine.cache_stats()
    }

    /// Admit a request, sampling queue depth first.
    pub(crate) fn enqueue(&mut self, req: SimRequest) {
        self.stats.queue_depth.record(self.batcher.pending() as u64);
        self.batcher.push(req);
    }

    /// Pop the next batch (round-robin across `(size, kind)` queues)
    /// holding at least `min_signals`, price it on the engine's workload
    /// decomposition, and go busy. Returns the modeled service time in ns,
    /// or `None` if nothing qualified.
    pub(crate) fn start_batch(&mut self, min_signals: usize) -> Result<Option<u64>> {
        let Some(batch) = self.batcher.pop_ready(min_signals) else {
            return Ok(None);
        };
        let total = batch.total_signals();
        let padded = batch.padded_signals();
        let eval = self.engine.plan_workload(batch.kind, batch.n, padded)?;
        let service_ns = eval.plan_ns.max(1.0).round() as u64;
        self.stats.batches += 1;
        self.stats.signals += total as u64;
        self.stats.padded_signals += padded as u64;
        self.stats.busy_ns += service_ns;
        self.stats.movement.add_assign(&eval.movement_plan);
        self.stats.occupancy_pct.record((total * 100 / padded) as u64);
        self.in_flight_signals = total;
        self.in_flight_service_ns = service_ns;
        self.in_flight_occupancy = (total * 100 / padded) as u64;
        self.in_flight_attr = eval.pass_attribution();
        self.in_flight = batch.requests;
        self.busy = true;
        Ok(Some(service_ns))
    }

    /// Finish the in-flight batch, returning its requests for latency
    /// accounting.
    pub(crate) fn finish_batch(&mut self) -> Vec<SimRequest> {
        self.busy = false;
        self.in_flight_signals = 0;
        self.stats.requests += self.in_flight.len() as u64;
        for req in &self.in_flight {
            *self.stats.kind_requests.entry(req.kind).or_insert(0) += 1;
        }
        std::mem::take(&mut self.in_flight)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    fn shard() -> Shard {
        let sys = SystemConfig::baseline().with_hw_opt();
        Shard::new(FftEngine::builder().system(&sys).build())
    }

    fn req1d(id: u64, n: usize, signals: usize, arrive_ns: u64) -> SimRequest {
        SimRequest { id, kind: WorkloadKind::Batch1d, n, signals, arrive_ns }
    }

    #[test]
    fn batch_lifecycle_prices_and_pads() {
        let mut s = shard();
        for id in 0..3u64 {
            s.enqueue(req1d(id, 8192, 2, id * 10));
        }
        assert_eq!(s.pending_requests(), 3);
        assert_eq!(s.pending_signals(), 6);
        assert!(!s.is_busy());
        let service = s.start_batch(1).unwrap().unwrap();
        assert!(service >= 1);
        assert!(s.is_busy());
        assert_eq!(s.pending_requests(), 0);
        assert_eq!(s.load_signals(), 6);
        assert_eq!(s.stats.signals, 6);
        assert_eq!(s.stats.padded_signals, 8); // 6 → padded to 8
        assert_eq!(s.stats.batches, 1);
        assert_eq!(s.stats.busy_ns, service);
        assert!(s.stats.movement.total() > 0.0);
        let done = s.finish_batch();
        assert_eq!(done.len(), 3);
        assert!(!s.is_busy());
        assert_eq!(s.stats.requests, 3);
        assert_eq!(s.load_signals(), 0);
    }

    #[test]
    fn start_batch_respects_min_signals() {
        let mut s = shard();
        s.enqueue(req1d(0, 64, 2, 0));
        assert!(s.start_batch(8).unwrap().is_none());
        assert!(!s.is_busy());
        assert!(s.start_batch(1).unwrap().is_some());
    }

    #[test]
    fn repeated_shapes_hit_the_plan_cache() {
        let mut s = shard();
        for round in 0..4u64 {
            s.enqueue(req1d(round, 8192, 4, 0));
            s.start_batch(1).unwrap().unwrap();
            s.finish_batch();
        }
        let (hits, misses) = s.cache_stats();
        assert_eq!((hits, misses), (3, 1));
    }

    #[test]
    fn kinds_are_priced_and_counted_separately() {
        let mut s = shard();
        s.enqueue(SimRequest { id: 0, kind: WorkloadKind::Batch1d, n: 8192, signals: 4, arrive_ns: 0 });
        let t1d = s.start_batch(1).unwrap().unwrap();
        s.finish_batch();
        s.enqueue(SimRequest { id: 1, kind: WorkloadKind::Fft2d, n: 8192, signals: 4, arrive_ns: 0 });
        let t2d = s.start_batch(1).unwrap().unwrap();
        s.finish_batch();
        // A 2D FFT of the same n runs two (smaller) passes plus transposes:
        // its modeled service time must differ from the 1D pricing.
        assert_ne!(t1d, t2d);
        assert_eq!(s.stats.kind_requests[&WorkloadKind::Batch1d], 1);
        assert_eq!(s.stats.kind_requests[&WorkloadKind::Fft2d], 1);
        // STFT decomposes into many window-size FFTs and still prices.
        s.enqueue(SimRequest { id: 2, kind: WorkloadKind::Stft, n: 8192, signals: 2, arrive_ns: 0 });
        assert!(s.start_batch(1).unwrap().unwrap() >= 1);
        s.finish_batch();
        assert_eq!(s.stats.requests, 3);
    }
}
