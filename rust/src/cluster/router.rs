//! Request → shard routing policies.
//!
//! Every policy is deterministic. The interesting trade-off is cache
//! affinity vs load balance: [`RoundRobinRouter`] spreads perfectly but
//! makes every shard plan every FFT shape (cold plan caches everywhere),
//! [`SizeAffinityRouter`] pins each `(kind, size)` shape to one home shard
//! so its engine's plan cache stays hot, [`LeastLoadedRouter`] chases
//! instantaneous queue depth at the cost of shape locality, and
//! [`CostAwareRouter`] learns per-`(kind, log2 n)` service estimates per
//! shard *class* from observed completions — the policy a heterogeneous
//! fleet needs, where a GPU-only shard may price the same batch several
//! times slower than a PIM-heavy one.
//!
//! Fault awareness: every policy avoids crashed shards while at least one
//! shard is up (requests routed during a total outage queue at the
//! policy's normal pick and serve after restart).

use std::collections::btree_map::Entry;
use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::workload::WorkloadKind;

use super::Shard;

/// A routing policy: pick a shard for each arriving request.
pub trait ShardRouter {
    fn name(&self) -> &'static str;

    /// Choose the destination shard for a `kind` request of FFT size `n`
    /// carrying `signals` signals. `shards` is never empty.
    fn route(&mut self, kind: WorkloadKind, n: usize, signals: usize, shards: &[Shard])
        -> usize;

    /// Feedback from the simulator: a batch of shape `(kind, n)` completed
    /// on a shard of class `class` at `service_ns_per_signal`. Default
    /// no-op; learning policies ([`CostAwareRouter`]) fold it into their
    /// estimates.
    fn observe(&mut self, kind: WorkloadKind, n: usize, class: &'static str, ns_per_signal: f64) {
        let _ = (kind, n, class, ns_per_signal);
    }
}

/// Indices of shards currently up, or every index during a total outage
/// (so the policy still returns something and work queues for restart).
fn alive(shards: &[Shard]) -> Vec<usize> {
    let up: Vec<usize> =
        (0..shards.len()).filter(|&i| !shards[i].is_down()).collect();
    if up.is_empty() {
        (0..shards.len()).collect()
    } else {
        up
    }
}

/// Cycle through shards in order, skipping crashed ones.
#[derive(Debug, Default)]
pub struct RoundRobinRouter {
    next: usize,
}

impl ShardRouter for RoundRobinRouter {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn route(
        &mut self,
        _kind: WorkloadKind,
        _n: usize,
        _signals: usize,
        shards: &[Shard],
    ) -> usize {
        for probe in 0..shards.len() {
            let s = (self.next + probe) % shards.len();
            if !shards[s].is_down() {
                self.next = self.next.wrapping_add(probe + 1);
                return s;
            }
        }
        // Total outage: keep the historical cycle.
        let s = self.next % shards.len();
        self.next = self.next.wrapping_add(1);
        s
    }
}

/// Sticky `(kind, size)` → shard assignment: the first time a shape appears
/// it is pinned to the shard with the fewest pinned shapes (ties to the
/// lowest index), and every later request of that shape follows it. Keeps
/// each engine's plan cache hot on its home shapes — a 2D FFT and a
/// convolution of the same `n` decompose into different pass shapes, so
/// they count as distinct homes. A crashed home spills (without re-pinning)
/// to the up shard with the fewest pinned shapes.
#[derive(Debug)]
pub struct SizeAffinityRouter {
    home: BTreeMap<(WorkloadKind, usize), usize>,
    shapes_per_shard: Vec<usize>,
}

impl SizeAffinityRouter {
    pub fn new(shards: usize) -> Self {
        Self { home: BTreeMap::new(), shapes_per_shard: vec![0; shards] }
    }
}

impl ShardRouter for SizeAffinityRouter {
    fn name(&self) -> &'static str {
        "size-affinity"
    }

    fn route(
        &mut self,
        kind: WorkloadKind,
        n: usize,
        _signals: usize,
        shards: &[Shard],
    ) -> usize {
        if let Some(&s) = self.home.get(&(kind, n)) {
            if !shards[s].is_down() {
                return s;
            }
            // Temporary spill while the home shard is down.
            return alive(shards)
                .into_iter()
                .min_by_key(|&i| (self.shapes_per_shard[i], i))
                .unwrap();
        }
        let s = alive(shards)
            .into_iter()
            .min_by_key(|&i| (self.shapes_per_shard[i], i))
            .unwrap();
        self.shapes_per_shard[s] += 1;
        self.home.insert((kind, n), s);
        s
    }
}

/// Send each request to the up shard with the fewest queued + in-flight
/// signals (ties to the lowest index).
#[derive(Debug, Default)]
pub struct LeastLoadedRouter;

impl ShardRouter for LeastLoadedRouter {
    fn name(&self) -> &'static str {
        "least-loaded"
    }

    fn route(
        &mut self,
        _kind: WorkloadKind,
        _n: usize,
        _signals: usize,
        shards: &[Shard],
    ) -> usize {
        alive(shards)
            .into_iter()
            .min_by_key(|&i| (shards[i].load_signals(), i))
            .unwrap()
    }
}

/// Learned cost-aware routing for heterogeneous fleets.
///
/// Keeps an EWMA (α = 0.25) of observed service time per padded signal,
/// keyed `(kind, log2 n, shard class)`, fed by [`ShardRouter::observe`]
/// from every completed batch. Routing minimizes the *projected* service
/// backlog `est(class) × (shard load + incoming signals)` over up shards —
/// i.e. load balancing in units of estimated time, not raw signals, so a
/// GPU-only shard absorbs proportionally less of a large-FFT mix than a
/// PIM-heavy one. Classes with no estimate yet score zero (optimistic
/// exploration: each class gets sampled before estimates dominate); until
/// *any* estimate exists for a shape the policy degenerates to exactly
/// least-loaded.
#[derive(Debug, Default)]
pub struct CostAwareRouter {
    est: BTreeMap<(WorkloadKind, u32, &'static str), f64>,
}

impl CostAwareRouter {
    const ALPHA: f64 = 0.25;

    fn estimate(&self, kind: WorkloadKind, n: usize, class: &'static str) -> Option<f64> {
        self.est.get(&(kind, n.trailing_zeros(), class)).copied()
    }
}

impl ShardRouter for CostAwareRouter {
    fn name(&self) -> &'static str {
        "cost-aware"
    }

    fn route(
        &mut self,
        kind: WorkloadKind,
        n: usize,
        signals: usize,
        shards: &[Shard],
    ) -> usize {
        let candidates = alive(shards);
        let known = candidates
            .iter()
            .any(|&i| self.estimate(kind, n, shards[i].spec().class.name()).is_some());
        if !known {
            // Least-loaded fallback until the first completion teaches us
            // anything about this shape.
            return candidates
                .into_iter()
                .min_by_key(|&i| (shards[i].load_signals(), i))
                .unwrap();
        }
        candidates
            .into_iter()
            .min_by(|&a, &b| {
                let score = |i: usize| {
                    let class = shards[i].spec().class.name();
                    let est = self.estimate(kind, n, class).unwrap_or(0.0);
                    est * (shards[i].load_signals() + signals) as f64
                };
                score(a).total_cmp(&score(b)).then(a.cmp(&b))
            })
            .unwrap()
    }

    fn observe(&mut self, kind: WorkloadKind, n: usize, class: &'static str, ns_per_signal: f64) {
        match self.est.entry((kind, n.trailing_zeros(), class)) {
            Entry::Vacant(v) => {
                v.insert(ns_per_signal);
            }
            Entry::Occupied(mut o) => {
                let e = o.get_mut();
                *e = *e * (1.0 - Self::ALPHA) + ns_per_signal * Self::ALPHA;
            }
        }
    }
}

/// CLI-facing router selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterKind {
    RoundRobin,
    SizeAffinity,
    LeastLoaded,
    CostAware,
}

impl RouterKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "round-robin" | "rr" => RouterKind::RoundRobin,
            "size-affinity" | "affinity" => RouterKind::SizeAffinity,
            "least-loaded" | "ll" => RouterKind::LeastLoaded,
            "cost-aware" | "cost" => RouterKind::CostAware,
            other => bail!(
                "unknown router '{other}' (round-robin|size-affinity|least-loaded|cost-aware)"
            ),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            RouterKind::RoundRobin => "round-robin",
            RouterKind::SizeAffinity => "size-affinity",
            RouterKind::LeastLoaded => "least-loaded",
            RouterKind::CostAware => "cost-aware",
        }
    }

    pub fn build(self, shards: usize) -> Box<dyn ShardRouter> {
        match self {
            RouterKind::RoundRobin => Box::new(RoundRobinRouter::default()),
            RouterKind::SizeAffinity => Box::new(SizeAffinityRouter::new(shards)),
            RouterKind::LeastLoaded => Box::new(LeastLoadedRouter),
            RouterKind::CostAware => Box::new(CostAwareRouter::default()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::FftEngine;
    use crate::cluster::{ShardSpec, SimRequest};
    use crate::config::SystemConfig;

    const K1D: WorkloadKind = WorkloadKind::Batch1d;

    fn shards(k: usize) -> Vec<Shard> {
        let sys = SystemConfig::baseline();
        (0..k).map(|_| Shard::new(FftEngine::builder().system(&sys).build())).collect()
    }

    fn hetero(gpu: usize, pim: usize) -> Vec<Shard> {
        let sys = SystemConfig::baseline();
        let mut v = Vec::new();
        for _ in 0..gpu {
            let spec = ShardSpec::gpu_only();
            v.push(Shard::with_spec(
                FftEngine::builder().system(&spec.system(&sys)).build(),
                spec,
                1.0,
            ));
        }
        for _ in 0..pim {
            let spec = ShardSpec::pim_heavy();
            v.push(Shard::with_spec(
                FftEngine::builder().system(&spec.system(&sys)).build(),
                spec,
                1.0,
            ));
        }
        v
    }

    #[test]
    fn round_robin_cycles() {
        let s = shards(3);
        let mut r = RouterKind::RoundRobin.build(3);
        let picks: Vec<usize> = (0..6).map(|_| r.route(K1D, 64, 1, &s)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn round_robin_skips_down_shards() {
        let mut s = shards(3);
        s[1].down = true;
        let mut r = RouterKind::RoundRobin.build(3);
        let picks: Vec<usize> = (0..4).map(|_| r.route(K1D, 64, 1, &s)).collect();
        assert_eq!(picks, vec![0, 2, 0, 2]);
    }

    #[test]
    fn affinity_is_sticky_and_balanced() {
        let s = shards(2);
        let mut r = RouterKind::SizeAffinity.build(2);
        let a = r.route(K1D, 32, 1, &s);
        let b = r.route(K1D, 64, 1, &s);
        let c = r.route(K1D, 128, 1, &s);
        // Distinct sizes spread across shards before doubling up.
        assert_ne!(a, b);
        // Same size always lands on its home shard.
        assert_eq!(r.route(K1D, 32, 1, &s), a);
        assert_eq!(r.route(K1D, 64, 1, &s), b);
        assert_eq!(r.route(K1D, 128, 1, &s), c);
    }

    #[test]
    fn affinity_spills_while_home_is_down_then_returns() {
        let mut s = shards(2);
        let mut r = RouterKind::SizeAffinity.build(2);
        let home = r.route(K1D, 32, 1, &s);
        s[home].down = true;
        let spill = r.route(K1D, 32, 1, &s);
        assert_ne!(spill, home);
        s[home].down = false;
        assert_eq!(r.route(K1D, 32, 1, &s), home, "pin survives the outage");
    }

    #[test]
    fn affinity_distinguishes_kinds_of_the_same_size() {
        let s = shards(2);
        let mut r = RouterKind::SizeAffinity.build(2);
        let a = r.route(WorkloadKind::Batch1d, 64, 1, &s);
        let b = r.route(WorkloadKind::Stft, 64, 1, &s);
        // Same n, different kinds: distinct shapes spread before doubling.
        assert_ne!(a, b);
        assert_eq!(r.route(WorkloadKind::Batch1d, 64, 1, &s), a);
        assert_eq!(r.route(WorkloadKind::Stft, 64, 1, &s), b);
    }

    #[test]
    fn least_loaded_prefers_empty_shards() {
        let mut s = shards(2);
        s[0].enqueue(SimRequest { id: 0, kind: K1D, n: 64, signals: 5, arrive_ns: 0 });
        let mut r = RouterKind::LeastLoaded.build(2);
        assert_eq!(r.route(K1D, 64, 1, &s), 1);
        s[1].enqueue(SimRequest { id: 1, kind: K1D, n: 64, signals: 9, arrive_ns: 0 });
        assert_eq!(r.route(K1D, 64, 1, &s), 0);
    }

    #[test]
    fn least_loaded_avoids_down_shards() {
        let mut s = shards(2);
        s[1].down = true;
        s[0].enqueue(SimRequest { id: 0, kind: K1D, n: 64, signals: 50, arrive_ns: 0 });
        let mut r = RouterKind::LeastLoaded.build(2);
        assert_eq!(r.route(K1D, 64, 1, &s), 0, "loaded but up beats empty but down");
    }

    #[test]
    fn cost_aware_starts_least_loaded_then_follows_estimates() {
        let s = hetero(1, 1); // shard 0 gpu-only, shard 1 pim-heavy
        let mut r = CostAwareRouter::default();
        // No estimates yet: exact least-loaded behavior (ties → index 0).
        assert_eq!(r.route(K1D, 16384, 1, &s), 0);
        // Completions teach it the gpu-only class is 4× slower.
        r.observe(K1D, 16384, "gpu-only", 4000.0);
        r.observe(K1D, 16384, "pim-heavy", 1000.0);
        assert_eq!(r.route(K1D, 16384, 1, &s), 1, "routes to the faster class");
        // The estimate is per (kind, log2 n): other shapes still explore.
        assert_eq!(r.route(K1D, 64, 1, &s), 0);
    }

    #[test]
    fn cost_aware_still_balances_within_a_class() {
        let mut s = hetero(1, 1);
        let mut r = CostAwareRouter::default();
        r.observe(K1D, 16384, "gpu-only", 1500.0);
        r.observe(K1D, 16384, "pim-heavy", 1000.0);
        // Pile enough load on the fast shard that the slow one's projected
        // backlog wins: 1000 × (21+1) > 1500 × (0+1).
        s[1].enqueue(SimRequest { id: 0, kind: K1D, n: 16384, signals: 21, arrive_ns: 0 });
        assert_eq!(r.route(K1D, 16384, 1, &s), 0);
    }

    #[test]
    fn cost_aware_ewma_converges() {
        let mut r = CostAwareRouter::default();
        r.observe(K1D, 64, "mixed", 1000.0);
        for _ in 0..50 {
            r.observe(K1D, 64, "mixed", 2000.0);
        }
        let e = r.estimate(K1D, 64, "mixed").unwrap();
        assert!((e - 2000.0).abs() < 1.0, "EWMA {e} should have converged to 2000");
    }

    #[test]
    fn parse_names() {
        assert_eq!(RouterKind::parse("rr").unwrap(), RouterKind::RoundRobin);
        assert_eq!(RouterKind::parse("size-affinity").unwrap(), RouterKind::SizeAffinity);
        assert_eq!(RouterKind::parse("least-loaded").unwrap().name(), "least-loaded");
        assert_eq!(RouterKind::parse("cost-aware").unwrap(), RouterKind::CostAware);
        assert_eq!(RouterKind::parse("cost").unwrap().name(), "cost-aware");
        assert!(RouterKind::parse("random").is_err());
    }
}
