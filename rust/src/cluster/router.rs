//! Request → shard routing policies.
//!
//! Every policy is deterministic. The interesting trade-off is cache
//! affinity vs load balance: [`RoundRobinRouter`] spreads perfectly but
//! makes every shard plan every FFT shape (cold plan caches everywhere),
//! [`SizeAffinityRouter`] pins each `(kind, size)` shape to one home shard
//! so its engine's plan cache stays hot, [`LeastLoadedRouter`] chases
//! instantaneous queue depth at the cost of shape locality.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::workload::WorkloadKind;

use super::Shard;

/// A routing policy: pick a shard for each arriving request.
pub trait ShardRouter {
    fn name(&self) -> &'static str;

    /// Choose the destination shard for a `kind` request of FFT size `n`
    /// carrying `signals` signals. `shards` is never empty.
    fn route(&mut self, kind: WorkloadKind, n: usize, signals: usize, shards: &[Shard])
        -> usize;
}

/// Cycle through shards in order.
#[derive(Debug, Default)]
pub struct RoundRobinRouter {
    next: usize,
}

impl ShardRouter for RoundRobinRouter {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn route(
        &mut self,
        _kind: WorkloadKind,
        _n: usize,
        _signals: usize,
        shards: &[Shard],
    ) -> usize {
        let s = self.next % shards.len();
        self.next = self.next.wrapping_add(1);
        s
    }
}

/// Sticky `(kind, size)` → shard assignment: the first time a shape appears
/// it is pinned to the shard with the fewest pinned shapes (ties to the
/// lowest index), and every later request of that shape follows it. Keeps
/// each engine's plan cache hot on its home shapes — a 2D FFT and a
/// convolution of the same `n` decompose into different pass shapes, so
/// they count as distinct homes.
#[derive(Debug)]
pub struct SizeAffinityRouter {
    home: BTreeMap<(WorkloadKind, usize), usize>,
    shapes_per_shard: Vec<usize>,
}

impl SizeAffinityRouter {
    pub fn new(shards: usize) -> Self {
        Self { home: BTreeMap::new(), shapes_per_shard: vec![0; shards] }
    }
}

impl ShardRouter for SizeAffinityRouter {
    fn name(&self) -> &'static str {
        "size-affinity"
    }

    fn route(
        &mut self,
        kind: WorkloadKind,
        n: usize,
        _signals: usize,
        _shards: &[Shard],
    ) -> usize {
        if let Some(&s) = self.home.get(&(kind, n)) {
            return s;
        }
        let s = self
            .shapes_per_shard
            .iter()
            .enumerate()
            .min_by_key(|&(i, &count)| (count, i))
            .map(|(i, _)| i)
            .unwrap();
        self.shapes_per_shard[s] += 1;
        self.home.insert((kind, n), s);
        s
    }
}

/// Send each request to the shard with the fewest queued + in-flight
/// signals (ties to the lowest index).
#[derive(Debug, Default)]
pub struct LeastLoadedRouter;

impl ShardRouter for LeastLoadedRouter {
    fn name(&self) -> &'static str {
        "least-loaded"
    }

    fn route(
        &mut self,
        _kind: WorkloadKind,
        _n: usize,
        _signals: usize,
        shards: &[Shard],
    ) -> usize {
        shards
            .iter()
            .enumerate()
            .min_by_key(|&(i, s)| (s.load_signals(), i))
            .map(|(i, _)| i)
            .unwrap()
    }
}

/// CLI-facing router selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterKind {
    RoundRobin,
    SizeAffinity,
    LeastLoaded,
}

impl RouterKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "round-robin" | "rr" => RouterKind::RoundRobin,
            "size-affinity" | "affinity" => RouterKind::SizeAffinity,
            "least-loaded" | "ll" => RouterKind::LeastLoaded,
            other => bail!("unknown router '{other}' (round-robin|size-affinity|least-loaded)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            RouterKind::RoundRobin => "round-robin",
            RouterKind::SizeAffinity => "size-affinity",
            RouterKind::LeastLoaded => "least-loaded",
        }
    }

    pub fn build(self, shards: usize) -> Box<dyn ShardRouter> {
        match self {
            RouterKind::RoundRobin => Box::new(RoundRobinRouter::default()),
            RouterKind::SizeAffinity => Box::new(SizeAffinityRouter::new(shards)),
            RouterKind::LeastLoaded => Box::new(LeastLoadedRouter),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::FftEngine;
    use crate::cluster::SimRequest;
    use crate::config::SystemConfig;

    const K1D: WorkloadKind = WorkloadKind::Batch1d;

    fn shards(k: usize) -> Vec<Shard> {
        let sys = SystemConfig::baseline();
        (0..k).map(|_| Shard::new(FftEngine::builder().system(&sys).build())).collect()
    }

    #[test]
    fn round_robin_cycles() {
        let s = shards(3);
        let mut r = RouterKind::RoundRobin.build(3);
        let picks: Vec<usize> = (0..6).map(|_| r.route(K1D, 64, 1, &s)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn affinity_is_sticky_and_balanced() {
        let s = shards(2);
        let mut r = RouterKind::SizeAffinity.build(2);
        let a = r.route(K1D, 32, 1, &s);
        let b = r.route(K1D, 64, 1, &s);
        let c = r.route(K1D, 128, 1, &s);
        // Distinct sizes spread across shards before doubling up.
        assert_ne!(a, b);
        // Same size always lands on its home shard.
        assert_eq!(r.route(K1D, 32, 1, &s), a);
        assert_eq!(r.route(K1D, 64, 1, &s), b);
        assert_eq!(r.route(K1D, 128, 1, &s), c);
    }

    #[test]
    fn affinity_distinguishes_kinds_of_the_same_size() {
        let s = shards(2);
        let mut r = RouterKind::SizeAffinity.build(2);
        let a = r.route(WorkloadKind::Batch1d, 64, 1, &s);
        let b = r.route(WorkloadKind::Stft, 64, 1, &s);
        // Same n, different kinds: distinct shapes spread before doubling.
        assert_ne!(a, b);
        assert_eq!(r.route(WorkloadKind::Batch1d, 64, 1, &s), a);
        assert_eq!(r.route(WorkloadKind::Stft, 64, 1, &s), b);
    }

    #[test]
    fn least_loaded_prefers_empty_shards() {
        let mut s = shards(2);
        s[0].enqueue(SimRequest { id: 0, kind: K1D, n: 64, signals: 5, arrive_ns: 0 });
        let mut r = RouterKind::LeastLoaded.build(2);
        assert_eq!(r.route(K1D, 64, 1, &s), 1);
        s[1].enqueue(SimRequest { id: 1, kind: K1D, n: 64, signals: 9, arrive_ns: 0 });
        assert_eq!(r.route(K1D, 64, 1, &s), 0);
    }

    #[test]
    fn parse_names() {
        assert_eq!(RouterKind::parse("rr").unwrap(), RouterKind::RoundRobin);
        assert_eq!(RouterKind::parse("size-affinity").unwrap(), RouterKind::SizeAffinity);
        assert_eq!(RouterKind::parse("least-loaded").unwrap().name(), "least-loaded");
        assert!(RouterKind::parse("random").is_err());
    }
}
