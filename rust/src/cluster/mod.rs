//! **L4 — the cluster**: a deterministic discrete-event simulator for
//! sharded FFT serving, plus SLO-aware capacity planning.
//!
//! The coordinator (L3) serves one worker's worth of traffic for real; this
//! layer answers the questions that come *before* buying hardware: how many
//! GPU+PIM shards does p99 ≤ SLO need at a million requests per second, and
//! which routing policy gets there cheapest? It simulates millions of trace
//! requests in virtual time — wall-clock seconds — because shards never
//! compute spectra: service time is the [`crate::backend::FftEngine`]'s own
//! cost estimate for each padded batch shape, i.e. the same §4.4.1/§5.1
//! models every paper figure is built from.
//!
//! Pieces:
//!
//! * [`event`](EventQueue) — virtual clock + deterministic event heap
//!   (FIFO tie-break, so identical seeds give bit-identical reports);
//! * [`Shard`] — an [`crate::backend::FftEngine`] behind a size-keyed queue
//!   with windowed batching (dispatch at `window_signals`, or on the
//!   `max_wait_us` deadline, or work-conservingly on completion) and padded
//!   power-of-two batch shapes;
//! * [`ShardSpec`] — per-shard hardware shape for heterogeneous fleets
//!   (device class, HBM stacks, PIM density, batch slots), priced by an
//!   engine built from exactly that spec;
//! * [`ShardRouter`] — pluggable routing: [`RouterKind::RoundRobin`]
//!   (spread, cold caches), [`RouterKind::SizeAffinity`] (each FFT size has
//!   a home shard, hot plan caches), [`RouterKind::LeastLoaded`] (chase
//!   queue depth), [`RouterKind::CostAware`] (learned per-class service
//!   estimates — the policy heterogeneous fleets want);
//! * [`FaultPlan`] — seeded fault injection: shard crash/restart timelines
//!   and slow-node stragglers, decided before virtual time starts, with
//!   requeue-or-fail accounting in the report's [`FailureSummary`];
//! * [`run_cluster`] — the simulation itself, producing a [`ClusterReport`]
//!   with log-bucketed latency percentiles (p50/p95/p99/p999), per-shard
//!   utilization, queue depth, batch occupancy, plan-cache hit rates,
//!   per-substrate data movement, and failure accounting — emitted as a
//!   JSON artifact by the `cluster` CLI subcommand;
//! * [`plan_capacity`] — binary search over shard count for the smallest
//!   cluster meeting a p99 SLO, with the full latency-vs-capacity probe
//!   curve in the answer — and [`plan_fleet`], the heterogeneous variant
//!   that searches fleet *shapes* (mix profiles × count) by fleet cost.
//!
//! Workloads come from [`crate::coordinator::Workload`]: open-loop
//! Poisson/burst/diurnal/flash-crowd arrivals over a size-mix profile.
//!
//! With [`ClusterConfig::threads`] set, plan evaluation fans out over the
//! work-stealing [`crate::runtime::ThreadPool`] before virtual time starts
//! (workers compute, the event core commits in FIFO order — see
//! [`warm_plans`]), so reports stay **byte-identical per seed for every
//! thread count**:
//!
//! ```
//! use pimacolaba::cluster::{run_cluster, ClusterConfig};
//! use pimacolaba::coordinator::{Arrival, SizeMix, Workload};
//! use pimacolaba::runtime::Parallelism;
//!
//! let mix = SizeMix::uniform(&[64, 4096]).unwrap();
//! let trace = Workload::new(Arrival::Poisson, 200_000.0, mix).unwrap().generate(200, 7);
//!
//! let mut cfg = ClusterConfig::default_hw();
//! cfg.shards = 2;
//! let sequential = run_cluster(&trace, &cfg).unwrap();
//! cfg.threads = Parallelism::Fixed(2);
//! let parallel = run_cluster(&trace, &cfg).unwrap();
//!
//! assert_eq!(sequential.requests, 200);
//! assert_eq!(sequential.to_json().to_string(), parallel.to_json().to_string());
//! ```

mod capacity;
mod event;
mod fault;
mod fleet;
mod router;
mod shard;
mod sim;

pub use capacity::{plan_capacity, plan_fleet, CapacityPlan, CapacityProbe, FleetPlan, FleetProbe};
pub use event::{Event, EventQueue};
pub use fault::{CrashMode, FailureSummary, FaultPlan};
pub use fleet::{parse_fleet, DeviceClass, ShardSpec};
pub use router::{
    CostAwareRouter, LeastLoadedRouter, RoundRobinRouter, RouterKind, ShardRouter,
    SizeAffinityRouter,
};
pub use shard::{Shard, ShardStats, SimRequest};
pub use sim::{
    run_cluster, run_cluster_traced, warm_plans, warm_plans_for, ClusterConfig, ClusterReport,
    ShardSummary,
};
