//! Functional execution of micro-ops on one PIM unit (ALU + register file +
//! its bank pair).

use anyhow::{bail, Result};

use crate::dram::{BankPair, Half, Word, LANES};

use super::{MicroOp, Operand, RegFile};

/// Mutable state of one PIM unit during functional simulation.
#[derive(Debug, Clone)]
pub struct UnitState {
    pub regs: RegFile,
    pub pair: BankPair,
}

impl UnitState {
    pub fn new(regs: usize, n_words: usize) -> Self {
        Self { regs: RegFile::new(regs), pair: BankPair::with_words(n_words) }
    }

    fn load(&self, op: Operand, side: Half) -> Word {
        match op {
            Operand::Reg(r) => self.regs.read(r),
            Operand::Row(h, w) => {
                // Cross-bank reads are allowed (the unit sits between its two
                // banks); `side` is only the executing ALU half.
                let _ = side;
                *self.pair.bank(h).word(w)
            }
        }
    }

    fn store(&mut self, op: Operand, w: Word) {
        match op {
            Operand::Reg(r) => self.regs.write(r, w),
            Operand::Row(h, word) => *self.pair.bank_mut(h).word_mut(word) = w,
        }
    }

    /// Execute one micro-op on the given bank side. `hw_maddsub` gates the
    /// §6.2 dual-write ops.
    pub fn exec(&mut self, op: &MicroOp, side: Half, hw_maddsub: bool) -> Result<()> {
        if op.needs_hw_opt() && !hw_maddsub {
            bail!("dual-write op {op:?} requires the hw-opt PIM ALU augmentation");
        }
        match *op {
            MicroOp::Mov { dst, src } => {
                let v = self.load(src, side);
                self.store(dst, v);
            }
            MicroOp::Add { dst, a, b, sub } => {
                let (va, vb) = (self.load(a, side), self.load(b, side));
                let mut out = [0.0; LANES];
                for l in 0..LANES {
                    out[l] = if sub { va[l] - vb[l] } else { va[l] + vb[l] };
                }
                self.store(dst, out);
            }
            MicroOp::Madd { dst, a, b, imm } => {
                let (va, vb) = (self.load(a, side), self.load(b, side));
                let mut out = [0.0; LANES];
                for l in 0..LANES {
                    out[l] = va[l] + imm * vb[l];
                }
                self.store(dst, out);
            }
            MicroOp::Mul { dst, a, b } => {
                let (va, vb) = (self.load(a, side), self.load(b, side));
                let mut out = [0.0; LANES];
                for l in 0..LANES {
                    out[l] = va[l] * vb[l];
                }
                self.store(dst, out);
            }
            MicroOp::Fma { dst, a, b, sub } => {
                let (vd, va, vb) = (self.load(dst, side), self.load(a, side), self.load(b, side));
                let mut out = [0.0; LANES];
                for l in 0..LANES {
                    out[l] = if sub { vd[l] - va[l] * vb[l] } else { vd[l] + va[l] * vb[l] };
                }
                self.store(dst, out);
            }
            MicroOp::AddSub { dst_add, dst_sub, a, b } => {
                let (va, vb) = (self.load(a, side), self.load(b, side));
                let mut oa = [0.0; LANES];
                let mut os = [0.0; LANES];
                for l in 0..LANES {
                    oa[l] = va[l] + vb[l];
                    os[l] = va[l] - vb[l];
                }
                self.store(dst_add, oa);
                self.store(dst_sub, os);
            }
            MicroOp::MaddSub { dst_add, dst_sub, a, b, imm } => {
                let (va, vb) = (self.load(a, side), self.load(b, side));
                let mut oa = [0.0; LANES];
                let mut os = [0.0; LANES];
                for l in 0..LANES {
                    let t = imm * vb[l];
                    oa[l] = va[l] + t;
                    os[l] = va[l] - t;
                }
                self.store(dst_add, oa);
                self.store(dst_sub, os);
            }
            MicroOp::Shift { dst, src, amt } => {
                let v = self.regs.read(src);
                let mut out = [0.0; LANES];
                for l in 0..LANES {
                    let from = (l as isize - amt as isize).rem_euclid(LANES as isize) as usize;
                    out[l] = v[from];
                }
                self.regs.write(dst, out);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unit() -> UnitState {
        let mut u = UnitState::new(16, 4);
        for l in 0..LANES {
            u.pair.even.set(0, l, l as f32);
            u.pair.odd.set(0, l, 10.0 + l as f32);
        }
        u
    }

    #[test]
    fn mov_row_to_reg_and_back() {
        let mut u = unit();
        u.exec(
            &MicroOp::Mov { dst: Operand::Reg(3), src: Operand::Row(Half::Even, 0) },
            Half::Even,
            false,
        )
        .unwrap();
        assert_eq!(u.regs.read(3)[5], 5.0);
        u.exec(
            &MicroOp::Mov { dst: Operand::Row(Half::Odd, 1), src: Operand::Reg(3) },
            Half::Odd,
            false,
        )
        .unwrap();
        assert_eq!(u.pair.odd.get(1, 5), 5.0);
    }

    #[test]
    fn madd_lanewise() {
        let mut u = unit();
        u.exec(
            &MicroOp::Madd {
                dst: Operand::Reg(0),
                a: Operand::Row(Half::Even, 0),
                b: Operand::Row(Half::Odd, 0),
                imm: -2.0,
            },
            Half::Even,
            false,
        )
        .unwrap();
        // lane l: l - 2*(10+l) = -20 - l
        for l in 0..LANES {
            assert_eq!(u.regs.read(0)[l], -20.0 - l as f32);
        }
    }

    #[test]
    fn maddsub_requires_hw_opt() {
        let mut u = unit();
        let op = MicroOp::MaddSub {
            dst_add: Operand::Reg(0),
            dst_sub: Operand::Reg(1),
            a: Operand::Row(Half::Even, 0),
            b: Operand::Row(Half::Odd, 0),
            imm: 1.0,
        };
        assert!(u.exec(&op, Half::Even, false).is_err());
        u.exec(&op, Half::Even, true).unwrap();
        for l in 0..LANES {
            assert_eq!(u.regs.read(0)[l], l as f32 + 10.0 + l as f32);
            assert_eq!(u.regs.read(1)[l], l as f32 - (10.0 + l as f32));
        }
    }

    #[test]
    fn shift_rotates_lanes() {
        let mut u = unit();
        u.exec(
            &MicroOp::Mov { dst: Operand::Reg(0), src: Operand::Row(Half::Even, 0) },
            Half::Even,
            false,
        )
        .unwrap();
        u.exec(&MicroOp::Shift { dst: 1, src: 0, amt: 2 }, Half::Even, false).unwrap();
        // dst[l] = src[l-2 mod 8]
        assert_eq!(u.regs.read(1)[2], 0.0);
        assert_eq!(u.regs.read(1)[0], 6.0);
    }

    #[test]
    fn add_sub_variant() {
        let mut u = unit();
        u.exec(
            &MicroOp::Add {
                dst: Operand::Reg(2),
                a: Operand::Row(Half::Odd, 0),
                b: Operand::Row(Half::Even, 0),
                sub: true,
            },
            Half::Odd,
            false,
        )
        .unwrap();
        for l in 0..LANES {
            assert_eq!(u.regs.read(2)[l], 10.0);
        }
    }
}
