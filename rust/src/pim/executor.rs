//! Command-stream execution: functional (against bank contents) and temporal
//! (command-level timing, the paper's §4.4.1 PIM performance model).
//!
//! Streams are *visited*, not materialized: routine generators push commands
//! into a [`Sink`], so a 2^18-point tile (≈10M commands) times in O(1)
//! memory. [`VecSink`] collects small streams for tests and functional runs.
//!
//! ## Timing model
//! Each broadcast command occupies one pseudo-channel command slot of
//! `issue_rate_divisor × tCCDL` (§2.3: PIM ops issue at half the column
//! rate). With `bank_pair_fused` the even/odd micro-ops retire in that
//! single slot (the unit drives both banks of its pair); otherwise each
//! micro-op serializes. Row activations charge tRP+tRAS per switching bank
//! (the "Rest" of paper Figs 9/13). Broadcast streams are identical across
//! units/channels, so one pass over the stream times the whole machine.
//!
//! ## Structural validation
//! Every command is checked against the strawman's constraints: register
//! indices within the configured RF, all row-buffer operands of a bank in
//! one row, per bank at most one column read and one column write per
//! command (two writes with the §6.2 dual-write port), and dual-write ops
//! gated on `hw_maddsub`.

use anyhow::{ensure, Result};

use crate::config::SystemConfig;
use crate::dram::{Half, RowTimer};
use crate::pimc::PassProvenance;

use super::{CmdKind, Operand, PimCommand, UnitState};

/// Time spent per bucket, ns (per broadcast domain — i.e. wall-clock, since
/// all domains run concurrently).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TimeBreakdown {
    pub madd_ns: f64,
    pub add_ns: f64,
    pub mov_ns: f64,
    pub shift_ns: f64,
    /// Row activations + precharge — the paper's "Rest".
    pub rest_ns: f64,
}

impl TimeBreakdown {
    pub fn total_ns(&self) -> f64 {
        self.madd_ns + self.add_ns + self.mov_ns + self.shift_ns + self.rest_ns
    }

    /// Compute-command time (MADD + ADD buckets).
    pub fn compute_ns(&self) -> f64 {
        self.madd_ns + self.add_ns
    }

    pub fn scaled(&self, k: f64) -> TimeBreakdown {
        TimeBreakdown {
            madd_ns: self.madd_ns * k,
            add_ns: self.add_ns * k,
            mov_ns: self.mov_ns * k,
            shift_ns: self.shift_ns * k,
            rest_ns: self.rest_ns * k,
        }
    }

    pub fn add_assign(&mut self, other: &TimeBreakdown) {
        self.madd_ns += other.madd_ns;
        self.add_ns += other.add_ns;
        self.mov_ns += other.mov_ns;
        self.shift_ns += other.shift_ns;
        self.rest_ns += other.rest_ns;
    }
}

/// Full report of a stream execution (one broadcast domain).
#[derive(Debug, Clone, Default)]
pub struct ExecReport {
    pub time: TimeBreakdown,
    /// Command slots consumed on the command bus.
    pub slots: u64,
    /// Broadcast commands issued (== stream length).
    pub commands: u64,
    /// Micro-op counts per kind — matches the paper's "pim-MADD operations
    /// per butterfly" accounting.
    pub madd_ops: u64,
    pub add_ops: u64,
    pub mov_ops: u64,
    pub shift_ops: u64,
    /// Row activations.
    pub row_switches: u64,
    /// What the [`crate::pimc::PassPipeline`] did while producing this
    /// stream (zeroed for streams that did not come through the pipeline,
    /// e.g. hand-built test commands). Filled in by the stream generator —
    /// the timing sink only observes lowered commands.
    pub provenance: PassProvenance,
}

impl ExecReport {
    /// Compute ops: MADD + ADD classes (the paper folds sw-opt ADDs into its
    /// per-butterfly "pim-MADD command" counts).
    pub fn compute_ops(&self) -> u64 {
        self.madd_ops + self.add_ops
    }
}

/// Receives a generated command stream.
pub trait Sink {
    fn accept(&mut self, cmd: &PimCommand) -> Result<()>;
}

/// Collects commands (tests / functional verification of small tiles).
#[derive(Default)]
pub struct VecSink(pub Vec<PimCommand>);

impl Sink for VecSink {
    fn accept(&mut self, cmd: &PimCommand) -> Result<()> {
        self.0.push(cmd.clone());
        Ok(())
    }
}

/// Validates + times a stream on the fly.
pub struct TimingSink<'a> {
    cfg: &'a SystemConfig,
    rows: RowTimer,
    rep: ExecReport,
    validate: bool,
}

impl<'a> TimingSink<'a> {
    pub fn new(cfg: &'a SystemConfig) -> Self {
        Self { cfg, rows: RowTimer::new(), rep: ExecReport::default(), validate: true }
    }

    /// Disable structural validation (hot benchmarking path; the test suite
    /// runs every routine through the validating configuration).
    pub fn unchecked(mut self) -> Self {
        self.validate = false;
        self
    }

    pub fn finish(self) -> ExecReport {
        let mut rep = self.rep;
        rep.row_switches = self.rows.switches();
        rep
    }
}

/// Validate one command against the strawman constraints.
pub fn validate_cmd(cfg: &SystemConfig, cmd: &PimCommand) -> Result<()> {
    let regs = cfg.pim.regs_per_unit;
    let wpr = cfg.hbm.words_per_row() as u32;
    let max_writes = if cfg.pim.hw_maddsub { 2 } else { 1 };
    for half in [Half::Even, Half::Odd] {
        let mut row = None;
        // Distinct words: the same open-row word feeding both bank sides of
        // a broadcast command is a single column access.
        let mut reads: Vec<u32> = Vec::new();
        let mut writes: Vec<u32> = Vec::new();
        for op in cmd.ops() {
            if op.needs_hw_opt() {
                ensure!(
                    cfg.pim.hw_maddsub,
                    "stream uses §6.2 dual-write ops but hw_maddsub is disabled"
                );
            }
            let mut check = |o: Operand, is_write: bool| -> Result<()> {
                match o {
                    Operand::Reg(r) => {
                        ensure!((r as usize) < regs, "register r{r} out of range (RF size {regs})");
                    }
                    Operand::Row(h, w) => {
                        if h == half {
                            let r = w / wpr;
                            match row {
                                None => row = Some(r),
                                Some(prev) => ensure!(
                                    prev == r,
                                    "command touches two rows ({prev}, {r}) of one bank"
                                ),
                            }
                            if is_write {
                                if !writes.contains(&w) {
                                    writes.push(w);
                                }
                            } else if !reads.contains(&w) {
                                reads.push(w);
                            }
                        }
                    }
                }
                Ok(())
            };
            for o in op.reads() {
                check(o, false)?;
            }
            for o in op.writes() {
                check(o, true)?;
            }
        }
        ensure!(
            reads.len() <= 1,
            "command performs {} column reads on one bank",
            reads.len()
        );
        ensure!(
            writes.len() <= max_writes,
            "command performs {} column writes on one bank (max {max_writes})",
            writes.len()
        );
    }
    Ok(())
}

impl Sink for TimingSink<'_> {
    #[inline]
    fn accept(&mut self, cmd: &PimCommand) -> Result<()> {
        if self.validate {
            validate_cmd(self.cfg, cmd)?;
        }
        let wpr = self.cfg.hbm.words_per_row() as u32;
        // Row activations for every referenced row (allocation-free walk —
        // this loop runs for every one of the tens of millions of commands a
        // figure sweep simulates; see EXPERIMENTS.md §Perf).
        let mut rest = 0.0;
        for op in cmd.ops() {
            op.for_each_row_operand(|h, w, _| {
                rest += self.rows.access(h, w / wpr, &self.cfg.hbm);
            });
        }
        self.rep.time.rest_ns += rest;
        let slots =
            if self.cfg.pim.bank_pair_fused { 1 } else { cmd.op_count() as u64 };
        self.rep.slots += slots;
        self.rep.commands += 1;
        // §2.3: only multi-bank *compute* broadcasts pay the half-rate
        // window; pim-MOV transfers between the open row and the PIM
        // registers are RD/WR-like column accesses at full column rate.
        let per_slot = if cmd.kind == CmdKind::Mov && self.cfg.pim.mov_full_rate {
            self.cfg.hbm.t_ccdl_ns
        } else {
            self.cfg.pim_slot_ns()
        };
        let t = slots as f64 * per_slot;
        match cmd.kind {
            CmdKind::Madd => {
                self.rep.time.madd_ns += t;
                self.rep.madd_ops += cmd.op_count() as u64;
            }
            CmdKind::Add => {
                self.rep.time.add_ns += t;
                self.rep.add_ops += cmd.op_count() as u64;
            }
            CmdKind::Mov => {
                self.rep.time.mov_ns += t;
                self.rep.mov_ops += cmd.op_count() as u64;
            }
            CmdKind::Shift => {
                self.rep.time.shift_ns += t;
                self.rep.shift_ops += cmd.op_count() as u64;
            }
        }
        Ok(())
    }
}

/// Functionally executes a stream against one unit's state.
pub struct FuncSink<'a, 'u> {
    cfg: &'a SystemConfig,
    unit: &'u mut UnitState,
    validate: bool,
}

impl<'a, 'u> FuncSink<'a, 'u> {
    pub fn new(cfg: &'a SystemConfig, unit: &'u mut UnitState) -> Self {
        Self { cfg, unit, validate: true }
    }

    /// Skip structural validation — for broadcast replay of a stream that
    /// was already validated once (identical across units by construction).
    pub fn unchecked(mut self) -> Self {
        self.validate = false;
        self
    }
}

impl Sink for FuncSink<'_, '_> {
    fn accept(&mut self, cmd: &PimCommand) -> Result<()> {
        if self.validate {
            validate_cmd(self.cfg, cmd)?;
        }
        let hw = self.cfg.pim.hw_maddsub;
        if let Some(op) = &cmd.even {
            self.unit.exec(op, Half::Even, hw)?;
        }
        if let Some(op) = &cmd.odd {
            self.unit.exec(op, Half::Odd, hw)?;
        }
        Ok(())
    }
}

/// Fan a stream out to several sinks (e.g. time + execute in one pass).
pub struct TeeSink<'s>(pub Vec<&'s mut dyn Sink>);

impl Sink for TeeSink<'_> {
    fn accept(&mut self, cmd: &PimCommand) -> Result<()> {
        for s in self.0.iter_mut() {
            s.accept(cmd)?;
        }
        Ok(())
    }
}

/// Slice-based convenience wrapper around the sinks.
pub struct Executor<'a> {
    cfg: &'a SystemConfig,
}

impl<'a> Executor<'a> {
    pub fn new(cfg: &'a SystemConfig) -> Self {
        Self { cfg }
    }

    /// Time a materialized stream.
    pub fn time_stream(&self, cmds: &[PimCommand]) -> Result<ExecReport> {
        let mut sink = TimingSink::new(self.cfg);
        for cmd in cmds {
            sink.accept(cmd)?;
        }
        Ok(sink.finish())
    }

    /// Functionally execute a materialized stream against one unit.
    pub fn run_stream(&self, cmds: &[PimCommand], unit: &mut UnitState) -> Result<()> {
        let mut sink = FuncSink::new(self.cfg, unit);
        for cmd in cmds {
            sink.accept(cmd)?;
        }
        Ok(())
    }

    /// Functional replay without per-command validation (stream already
    /// validated once — broadcast is identical across units).
    pub fn run_stream_unchecked(&self, cmds: &[PimCommand], unit: &mut UnitState) -> Result<()> {
        let mut sink = FuncSink::new(self.cfg, unit).unchecked();
        for cmd in cmds {
            sink.accept(cmd)?;
        }
        Ok(())
    }

    /// Functional + timing over several units sharing the broadcast stream.
    pub fn broadcast(&self, cmds: &[PimCommand], units: &mut [UnitState]) -> Result<ExecReport> {
        for unit in units.iter_mut() {
            self.run_stream(cmds, unit)?;
        }
        self.time_stream(cmds)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pim::MicroOp;

    fn cfg() -> SystemConfig {
        SystemConfig::baseline()
    }

    fn mov(dst: Operand, src: Operand) -> PimCommand {
        PimCommand::single(CmdKind::Mov, MicroOp::Mov { dst, src })
    }

    #[test]
    fn slot_accounting_fused_vs_not() {
        let mut c = cfg();
        let cmd = PimCommand::pair(
            CmdKind::Madd,
            MicroOp::Madd {
                dst: Operand::Reg(0),
                a: Operand::Row(Half::Even, 0),
                b: Operand::Reg(1),
                imm: 1.0,
            },
            MicroOp::Madd {
                dst: Operand::Reg(2),
                a: Operand::Row(Half::Odd, 0),
                b: Operand::Reg(3),
                imm: 1.0,
            },
        );
        let rep = Executor::new(&c).time_stream(std::slice::from_ref(&cmd)).unwrap();
        assert_eq!(rep.slots, 1);
        assert_eq!(rep.madd_ops, 2);
        assert!((rep.time.madd_ns - c.pim_slot_ns()).abs() < 1e-9);

        c.pim.bank_pair_fused = false;
        let rep2 = Executor::new(&c).time_stream(std::slice::from_ref(&cmd)).unwrap();
        assert_eq!(rep2.slots, 2);
        assert!((rep2.time.madd_ns - 2.0 * c.pim_slot_ns()).abs() < 1e-9);
    }

    #[test]
    fn row_switch_charged_once_per_row() {
        let c = cfg();
        let cmds = vec![
            mov(Operand::Reg(0), Operand::Row(Half::Even, 0)),
            mov(Operand::Reg(1), Operand::Row(Half::Even, 1)), // same row (32 words/row)
            mov(Operand::Reg(2), Operand::Row(Half::Even, 40)), // row 1
            mov(Operand::Reg(3), Operand::Row(Half::Even, 2)),  // back to row 0
        ];
        let rep = Executor::new(&c).time_stream(&cmds).unwrap();
        assert_eq!(rep.row_switches, 3); // cold + 2 switches
        assert!((rep.time.rest_ns - 3.0 * c.hbm.row_switch_ns()).abs() < 1e-9);
    }

    #[test]
    fn rejects_two_rows_same_bank_in_one_command() {
        let c = cfg();
        let bad = PimCommand::single(
            CmdKind::Add,
            MicroOp::Add {
                dst: Operand::Reg(0),
                a: Operand::Row(Half::Even, 0),
                b: Operand::Row(Half::Even, 100),
                sub: false,
            },
        );
        assert!(Executor::new(&c).time_stream(&[bad]).is_err());
    }

    #[test]
    fn rejects_two_reads_same_bank() {
        let c = cfg();
        let bad = PimCommand::single(
            CmdKind::Add,
            MicroOp::Add {
                dst: Operand::Reg(0),
                a: Operand::Row(Half::Even, 0),
                b: Operand::Row(Half::Even, 1),
                sub: false,
            },
        );
        assert!(Executor::new(&c).time_stream(&[bad]).is_err());
    }

    #[test]
    fn second_write_needs_hw_opt() {
        let c = cfg().with_hw_opt();
        let cmd = PimCommand::single(
            CmdKind::Madd,
            MicroOp::MaddSub {
                dst_add: Operand::Row(Half::Even, 0),
                dst_sub: Operand::Row(Half::Even, 1),
                a: Operand::Row(Half::Even, 2),
                b: Operand::Reg(0),
                imm: 0.5,
            },
        );
        assert!(Executor::new(&c).time_stream(std::slice::from_ref(&cmd)).is_ok());
        let base = cfg();
        assert!(Executor::new(&base).time_stream(&[cmd]).is_err());
    }

    #[test]
    fn rejects_out_of_range_register() {
        let c = cfg();
        let bad = mov(Operand::Reg(16), Operand::Row(Half::Even, 0));
        assert!(Executor::new(&c).time_stream(&[bad]).is_err());
    }

    #[test]
    fn functional_matches_unit_semantics() {
        let c = cfg();
        let mut unit = UnitState::new(16, 4);
        unit.pair.even.set(0, 0, 2.0);
        let cmds = vec![
            mov(Operand::Reg(0), Operand::Row(Half::Even, 0)),
            PimCommand::single(
                CmdKind::Madd,
                MicroOp::Madd {
                    dst: Operand::Row(Half::Even, 1),
                    a: Operand::Reg(0),
                    b: Operand::Reg(0),
                    imm: 3.0,
                },
            ),
        ];
        Executor::new(&c).run_stream(&cmds, &mut unit).unwrap();
        assert_eq!(unit.pair.even.get(1, 0), 8.0);
    }

    #[test]
    fn tee_sink_fans_out() {
        let _c = cfg();
        let mut v1 = VecSink::default();
        let mut v2 = VecSink::default();
        {
            let mut tee = TeeSink(vec![&mut v1, &mut v2]);
            tee.accept(&mov(Operand::Reg(0), Operand::Row(Half::Even, 0))).unwrap();
        }
        assert_eq!(v1.0.len(), 1);
        assert_eq!(v2.0.len(), 1);
    }
}
