//! The PIM unit simulator: ISA, register file, SIMD ALU (with the §6.2
//! MADD+SUB augmentation), and an executor that runs broadcast command
//! streams both **functionally** (against simulated bank contents, so every
//! routine's numerics are validated against the reference FFT) and
//! **temporally** (command-level timing per §4.4.1's model).

mod executor;
mod isa;
mod regfile;
mod unit;

pub use executor::{validate_cmd, ExecReport, Executor, FuncSink, Sink, TeeSink, TimeBreakdown, TimingSink, VecSink};
pub use isa::{CmdKind, MicroOp, Operand, PimCommand};
pub use regfile::RegFile;
pub use unit::UnitState;
