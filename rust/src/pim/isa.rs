//! The PIM instruction set (paper §2.3/§4.1/§6.2).
//!
//! A [`PimCommand`] is what the (PIM-aware) GPU broadcasts to every PIM unit
//! of a pseudo channel: up to two mirrored [`MicroOp`]s, one executed by the
//! even-bank side of the unit and one by the odd-bank side. With
//! `bank_pair_fused` both micro-ops retire in a single command slot — the
//! paper's designs pair banks per unit exactly to expose this; with the
//! conservative setting each op costs its own slot.
//!
//! Operands address either the unit's register file or an open-row word of
//! one of the two banks; twiddle components arrive as 32-bit immediates in
//! the command payload (§4.3 "online or offline computation of twiddle
//! factor components" — counted as command/constant traffic, footnote 3).

use crate::dram::Half;

/// An ALU operand.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Operand {
    /// Register file entry (256-bit, 8 lanes).
    Reg(u8),
    /// Word `word` of bank `half` — must be within the currently open row
    /// (the executor charges a row switch otherwise).
    Row(Half, u32),
}

impl Operand {
    pub fn row(self) -> Option<(Half, u32)> {
        match self {
            Operand::Row(h, w) => Some((h, w)),
            Operand::Reg(_) => None,
        }
    }
}

/// One lane-parallel micro-op executed by one bank-side of a PIM unit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MicroOp {
    /// `dst = src` (register ↔ row-buffer move; pim-MOV).
    Mov { dst: Operand, src: Operand },
    /// `dst = a ± b` (pim-ADD; `sub` selects subtraction).
    Add { dst: Operand, a: Operand, b: Operand, sub: bool },
    /// `dst = a + imm·b` (pim-MADD, Fig 7/14).
    Madd { dst: Operand, a: Operand, b: Operand, imm: f32 },
    /// `dst = a · b` lane-wise (vector twiddles — baseline mapping only,
    /// where per-lane twiddles defeat scalar immediates).
    Mul { dst: Operand, a: Operand, b: Operand },
    /// `dst = dst ± a · b` (accumulating MAC, vector twiddles).
    Fma { dst: Operand, a: Operand, b: Operand, sub: bool },
    /// `dst1 = a + b`, `dst2 = a − b` — §6.2 dual-write augmentation
    /// applied to a trivial butterfly.
    AddSub { dst_add: Operand, dst_sub: Operand, a: Operand, b: Operand },
    /// `dst1 = a + imm·b`, `dst2 = a − imm·b` — the §6.2 pim-MADD+SUB.
    MaddSub { dst_add: Operand, dst_sub: Operand, a: Operand, b: Operand, imm: f32 },
    /// Cross-lane rotate of a register by `amt` lanes (pim-SHIFT) — the
    /// §4.2.2 cost the strided mapping exists to avoid.
    Shift { dst: u8, src: u8, amt: i8 },
}

impl MicroOp {
    /// True if this op needs the dual register-file write port (§6.2).
    pub fn needs_hw_opt(&self) -> bool {
        matches!(self, MicroOp::AddSub { .. } | MicroOp::MaddSub { .. })
    }

    /// Operands read by this op.
    pub fn reads(&self) -> Vec<Operand> {
        match *self {
            MicroOp::Mov { src, .. } => vec![src],
            MicroOp::Add { a, b, .. } | MicroOp::Madd { a, b, .. } | MicroOp::Mul { a, b, .. } => {
                vec![a, b]
            }
            MicroOp::Fma { dst, a, b, .. } => vec![dst, a, b],
            MicroOp::AddSub { a, b, .. } | MicroOp::MaddSub { a, b, .. } => vec![a, b],
            MicroOp::Shift { src, .. } => vec![Operand::Reg(src)],
        }
    }

    /// Visit every row-buffer operand without allocating:
    /// `f(half, word, is_write)`. This is the hot-path accessor — the
    /// timing sink calls it for every simulated command (tens of millions
    /// per figure sweep); `reads()`/`writes()` remain for tests/validation.
    #[inline]
    pub fn for_each_row_operand(&self, mut f: impl FnMut(Half, u32, bool)) {
        let mut r = |o: Operand| {
            if let Operand::Row(h, w) = o {
                f(h, w, false)
            }
        };
        match *self {
            MicroOp::Mov { dst, src } => {
                r(src);
                if let Operand::Row(h, w) = dst {
                    f(h, w, true)
                }
            }
            MicroOp::Add { dst, a, b, .. }
            | MicroOp::Madd { dst, a, b, .. }
            | MicroOp::Mul { dst, a, b } => {
                r(a);
                r(b);
                if let Operand::Row(h, w) = dst {
                    f(h, w, true)
                }
            }
            MicroOp::Fma { dst, a, b, .. } => {
                r(a);
                r(b);
                if let Operand::Row(h, w) = dst {
                    f(h, w, false); // accumulator read
                    f(h, w, true);
                }
            }
            MicroOp::AddSub { dst_add, dst_sub, a, b }
            | MicroOp::MaddSub { dst_add, dst_sub, a, b, .. } => {
                r(a);
                r(b);
                for d in [dst_add, dst_sub] {
                    if let Operand::Row(h, w) = d {
                        f(h, w, true)
                    }
                }
            }
            MicroOp::Shift { .. } => {}
        }
    }

    /// Operands written by this op.
    pub fn writes(&self) -> Vec<Operand> {
        match *self {
            MicroOp::Mov { dst, .. }
            | MicroOp::Add { dst, .. }
            | MicroOp::Madd { dst, .. }
            | MicroOp::Mul { dst, .. }
            | MicroOp::Fma { dst, .. } => vec![dst],
            MicroOp::AddSub { dst_add, dst_sub, .. }
            | MicroOp::MaddSub { dst_add, dst_sub, .. } => vec![dst_add, dst_sub],
            MicroOp::Shift { dst, .. } => vec![Operand::Reg(dst)],
        }
    }
}

/// Statistic bucket of a command (paper Figs 9/13 break time down by these).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CmdKind {
    /// pim-MADD (includes the §6.2 MADD+SUB).
    Madd,
    /// pim-ADD (includes dual-write ADD+SUB).
    Add,
    /// pim-MOV: row-buffer ↔ register moves.
    Mov,
    /// pim-SHIFT: cross-lane shifts (baseline mapping only).
    Shift,
}

/// One broadcast command: mirrored micro-ops for the even/odd bank sides.
#[derive(Debug, Clone, PartialEq)]
pub struct PimCommand {
    pub even: Option<MicroOp>,
    pub odd: Option<MicroOp>,
    pub kind: CmdKind,
}

impl PimCommand {
    /// Paired command engaging both bank sides.
    pub fn pair(kind: CmdKind, even: MicroOp, odd: MicroOp) -> Self {
        Self { even: Some(even), odd: Some(odd), kind }
    }

    /// Single-sided command.
    pub fn single(kind: CmdKind, op: MicroOp) -> Self {
        Self { even: Some(op), odd: None, kind }
    }

    pub fn ops(&self) -> impl Iterator<Item = &MicroOp> {
        self.even.iter().chain(self.odd.iter())
    }

    /// Number of micro-ops (1 or 2).
    pub fn op_count(&self) -> usize {
        self.even.is_some() as usize + self.odd.is_some() as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::Half;

    #[test]
    fn reads_writes_enumeration() {
        let op = MicroOp::Madd {
            dst: Operand::Reg(0),
            a: Operand::Row(Half::Even, 3),
            b: Operand::Reg(1),
            imm: 0.5,
        };
        assert_eq!(op.reads().len(), 2);
        assert_eq!(op.writes(), vec![Operand::Reg(0)]);
        assert!(!op.needs_hw_opt());
    }

    #[test]
    fn maddsub_is_hw_opt() {
        let op = MicroOp::MaddSub {
            dst_add: Operand::Reg(0),
            dst_sub: Operand::Reg(1),
            a: Operand::Reg(2),
            b: Operand::Reg(3),
            imm: 1.0,
        };
        assert!(op.needs_hw_opt());
        assert_eq!(op.writes().len(), 2);
    }

    #[test]
    fn command_op_count() {
        let mv = MicroOp::Mov { dst: Operand::Reg(0), src: Operand::Row(Half::Even, 0) };
        assert_eq!(PimCommand::single(CmdKind::Mov, mv).op_count(), 1);
        assert_eq!(PimCommand::pair(CmdKind::Mov, mv, mv).op_count(), 2);
    }
}
