//! The PIM unit register file: `regs_per_unit` 256-bit entries (Table 1: 16)
//! shared by both bank sides of the unit.

use crate::dram::{Word, LANES};

/// Register file of one PIM unit.
#[derive(Debug, Clone)]
pub struct RegFile {
    regs: Vec<Word>,
}

impl RegFile {
    pub fn new(n: usize) -> Self {
        Self { regs: vec![[0.0; LANES]; n] }
    }

    pub fn len(&self) -> usize {
        self.regs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.regs.is_empty()
    }

    /// Read a register; panics on out-of-range index (the routine generators
    /// are responsible for respecting the configured RF size, and the
    /// executor validates indices up front).
    pub fn read(&self, r: u8) -> Word {
        self.regs[r as usize]
    }

    pub fn write(&mut self, r: u8, w: Word) {
        self.regs[r as usize] = w;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_write() {
        let mut rf = RegFile::new(16);
        assert_eq!(rf.len(), 16);
        let mut w = [0.0; LANES];
        w[3] = 9.0;
        rf.write(2, w);
        assert_eq!(rf.read(2)[3], 9.0);
        assert_eq!(rf.read(0)[0], 0.0);
    }

    #[test]
    #[should_panic]
    fn out_of_range_panics() {
        RegFile::new(4).read(4);
    }
}
