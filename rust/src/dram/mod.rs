//! DRAM substrate: bank storage, row-buffer state, and command-level timing
//! for the strawman HBM-PIM architecture (paper Fig 3, Table 1).
//!
//! The unit of storage is the 256-bit DRAM *word* — 8 f32 lanes, matching
//! the PIM ALU width. A PIM unit is shared by a **bank pair**: the even bank
//! holds real components, the odd bank imaginary components (paper Fig 6 ❶❻),
//! so one broadcast command can engage mirrored re/im micro-ops on both banks.

mod bank;
mod timing;

pub use bank::{Bank, BankPair};
pub use timing::RowTimer;

/// f32 lanes per DRAM word (256-bit bank I/O ÷ 32-bit operands, §2.3).
pub const LANES: usize = 8;

/// One SIMD word: 8 f32 lanes.
pub type Word = [f32; LANES];

/// Which bank of a PIM unit's pair an operand lives in.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Half {
    /// Even bank — real components.
    Even,
    /// Odd bank — imaginary components.
    Odd,
}

impl Half {
    pub fn index(self) -> usize {
        match self {
            Half::Even => 0,
            Half::Odd => 1,
        }
    }
}
