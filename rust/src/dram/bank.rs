//! Functional bank storage at word granularity.

use super::{Half, Word, LANES};

/// One DRAM bank: a flat array of 256-bit words plus its row-buffer state.
///
/// Storage is allocated lazily up to the word range a routine touches; the
/// configured `rows_per_bank` capacity is enforced by the mapping layer, not
/// here.
#[derive(Debug, Clone, Default)]
pub struct Bank {
    words: Vec<Word>,
}

impl Bank {
    pub fn with_words(n_words: usize) -> Self {
        Self { words: vec![[0.0; LANES]; n_words] }
    }

    pub fn n_words(&self) -> usize {
        self.words.len()
    }

    pub fn word(&self, w: u32) -> &Word {
        &self.words[w as usize]
    }

    pub fn word_mut(&mut self, w: u32) -> &mut Word {
        &mut self.words[w as usize]
    }

    pub fn get(&self, w: u32, lane: usize) -> f32 {
        self.words[w as usize][lane]
    }

    pub fn set(&mut self, w: u32, lane: usize, v: f32) {
        self.words[w as usize][lane] = v;
    }
}

/// The bank pair served by one PIM unit (even = re, odd = im).
#[derive(Debug, Clone, Default)]
pub struct BankPair {
    pub even: Bank,
    pub odd: Bank,
}

impl BankPair {
    pub fn with_words(n_words: usize) -> Self {
        Self { even: Bank::with_words(n_words), odd: Bank::with_words(n_words) }
    }

    pub fn bank(&self, half: Half) -> &Bank {
        match half {
            Half::Even => &self.even,
            Half::Odd => &self.odd,
        }
    }

    pub fn bank_mut(&mut self, half: Half) -> &mut Bank {
        match half {
            Half::Even => &mut self.even,
            Half::Odd => &mut self.odd,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn word_get_set() {
        let mut b = Bank::with_words(4);
        b.set(2, 5, 1.25);
        assert_eq!(b.get(2, 5), 1.25);
        assert_eq!(b.get(2, 4), 0.0);
        assert_eq!(b.n_words(), 4);
    }

    #[test]
    fn pair_halves_are_independent() {
        let mut p = BankPair::with_words(2);
        p.bank_mut(Half::Even).set(0, 0, 1.0);
        p.bank_mut(Half::Odd).set(0, 0, 2.0);
        assert_eq!(p.bank(Half::Even).get(0, 0), 1.0);
        assert_eq!(p.bank(Half::Odd).get(0, 0), 2.0);
    }
}
