//! Row-buffer state machine: charges the tRP+tRAS row-switch penalty the
//! paper's "Rest" bucket is made of (§4.4.1: "we deduce the exact DRAM
//! commands needed ... including row activations").

use crate::config::HbmConfig;

use super::Half;

/// Open-row tracker for one bank pair.
///
/// The command streams broadcast to every unit in a pseudo channel are
/// identical, so one tracker models the row behaviour of all banks in the
/// broadcast domain.
#[derive(Debug, Clone, Default)]
pub struct RowTimer {
    open: [Option<u32>; 2],
    switches: u64,
}

impl RowTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record an access to `row` in bank `half`; returns the ns penalty
    /// (0 for a row-buffer hit, tRP+tRAS for a switch or cold activation).
    #[inline]
    pub fn access(&mut self, half: Half, row: u32, hbm: &HbmConfig) -> f64 {
        let slot = &mut self.open[half.index()];
        if *slot == Some(row) {
            0.0
        } else {
            *slot = Some(row);
            self.switches += 1;
            hbm.row_switch_ns()
        }
    }

    /// Total row activations performed.
    pub fn switches(&self) -> u64 {
        self.switches
    }

    /// Currently open row of a bank (None if never activated).
    pub fn open_row(&self, half: Half) -> Option<u32> {
        self.open[half.index()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_is_free_switch_costs() {
        let hbm = HbmConfig::hbm3();
        let mut t = RowTimer::new();
        assert!(t.access(Half::Even, 0, &hbm) > 0.0); // cold activation
        assert_eq!(t.access(Half::Even, 0, &hbm), 0.0); // hit
        assert_eq!(t.access(Half::Even, 0, &hbm), 0.0);
        let p = t.access(Half::Even, 1, &hbm); // switch
        assert!((p - (15.0 + 33.0)).abs() < 1e-9);
        assert_eq!(t.switches(), 2);
    }

    #[test]
    fn halves_track_independently() {
        let hbm = HbmConfig::hbm3();
        let mut t = RowTimer::new();
        t.access(Half::Even, 3, &hbm);
        assert!(t.access(Half::Odd, 3, &hbm) > 0.0); // odd bank still cold
        assert_eq!(t.open_row(Half::Even), Some(3));
        assert_eq!(t.open_row(Half::Odd), Some(3));
    }
}
