//! Data mapping of FFT batches onto PIM bank pairs (paper §4.2, Fig 6).
//!
//! * [`StridedMapping`] — the paper's chosen design (§4.2.2): each SIMD lane
//!   holds one complete FFT, real components in the even bank and imaginary
//!   in the odd bank, elements stored in bit-reversed order along the word
//!   axis (the GPU writes them that way when staging — §7.2). All interacting
//!   elements share a lane ⇒ **no cross-SIMD shifts**, and one broadcast
//!   command advances 8 FFTs per unit.
//! * [`BaselineMapping`] — the straw alternative of Fig 9: one FFT spans the
//!   8 lanes of consecutive words. Early stages interact *across* lanes
//!   (costly pim-SHIFT), and per-lane twiddles defeat immediate broadcast,
//!   forcing twiddle-vector loads from a reserved table region.

mod baseline;
mod strided;

pub use baseline::BaselineMapping;
pub use strided::StridedMapping;

use crate::config::SystemConfig;

/// Capacity/placement summary shared by the two mappings.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Footprint {
    /// Words used per bank of the pair.
    pub words_per_bank: usize,
    /// Rows touched per bank.
    pub rows_per_bank: usize,
    /// FFTs resident per PIM unit.
    pub ffts_per_unit: usize,
}

/// Words → rows for the given system.
pub fn rows_for(words: usize, sys: &SystemConfig) -> usize {
    words.div_ceil(sys.hbm.words_per_row())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_for_rounds_up() {
        let sys = SystemConfig::baseline();
        assert_eq!(rows_for(1, &sys), 1);
        assert_eq!(rows_for(32, &sys), 1);
        assert_eq!(rows_for(33, &sys), 2);
        let rb2k = SystemConfig::rb2k();
        assert_eq!(rows_for(64, &rb2k), 1);
    }
}
