//! The §4.2.2 strided mapping: one FFT per SIMD lane.

use anyhow::{ensure, Result};

use crate::config::SystemConfig;
use crate::dram::{Half, LANES};
use crate::fft::{bit_reverse_permutation, is_pow2, SoaVec};
use crate::pim::UnitState;

use super::Footprint;

/// Placement of up to [`LANES`] size-`n` FFTs into one bank pair.
#[derive(Debug, Clone)]
pub struct StridedMapping {
    n: usize,
    perm: Vec<usize>,
}

impl StridedMapping {
    /// Create a mapping for FFT size `n`, validating the paper's §4.2 size
    /// limits against the system configuration.
    pub fn new(n: usize, sys: &SystemConfig) -> Result<Self> {
        ensure!(is_pow2(n) && n >= 2, "FFT size must be a power of two >= 2, got {n}");
        ensure!(
            n <= sys.max_strided_fft(),
            "FFT size {n} exceeds the strided-mapping limit {} (§4.2.2)",
            sys.max_strided_fft()
        );
        ensure!(
            n <= sys.max_bankpair_fft(),
            "FFT size {n} exceeds bank-pair capacity {} (§4.2.1)",
            sys.max_bankpair_fft()
        );
        Ok(Self { n, perm: bit_reverse_permutation(n) })
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Word index holding element `elem` (post-bit-reversal position).
    pub fn word_of(&self, elem: usize) -> u32 {
        debug_assert!(elem < self.n);
        elem as u32
    }

    /// Memory footprint per unit.
    pub fn footprint(&self, sys: &SystemConfig) -> Footprint {
        Footprint {
            words_per_bank: self.n,
            rows_per_bank: super::rows_for(self.n, sys),
            ffts_per_unit: LANES,
        }
    }

    /// Stage inputs: FFT `slot`'s natural-order signal lands in lane `slot`,
    /// bit-reversed along the word axis (re → even bank, im → odd bank).
    pub fn load(&self, ffts: &[SoaVec], unit: &mut UnitState) -> Result<()> {
        ensure!(ffts.len() <= LANES, "at most {LANES} FFTs per unit, got {}", ffts.len());
        for f in ffts {
            ensure!(f.len() == self.n, "FFT length {} != mapping size {}", f.len(), self.n);
        }
        ensure!(
            unit.pair.even.n_words() >= self.n,
            "unit bank too small: {} words < {}",
            unit.pair.even.n_words(),
            self.n
        );
        for (lane, f) in ffts.iter().enumerate() {
            for w in 0..self.n {
                let src = self.perm[w];
                unit.pair.bank_mut(Half::Even).set(w as u32, lane, f.re[src]);
                unit.pair.bank_mut(Half::Odd).set(w as u32, lane, f.im[src]);
            }
        }
        Ok(())
    }

    /// Read FFT `slot`'s spectrum back (DIT leaves results in natural order).
    pub fn read_out(&self, unit: &UnitState, slot: usize) -> SoaVec {
        let mut out = SoaVec::zeros(self.n);
        for w in 0..self.n {
            out.re[w] = unit.pair.bank(Half::Even).get(w as u32, slot);
            out.im[w] = unit.pair.bank(Half::Odd).get(w as u32, slot);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_applies_bit_reversal() {
        let sys = SystemConfig::baseline();
        let m = StridedMapping::new(8, &sys).unwrap();
        let mut f = SoaVec::zeros(8);
        for i in 0..8 {
            f.set(i, i as f32, -(i as f32));
        }
        let mut unit = UnitState::new(16, 8);
        m.load(std::slice::from_ref(&f), &mut unit).unwrap();
        // word w holds element bitrev(w): word 1 ← element 4.
        assert_eq!(unit.pair.even.get(1, 0), 4.0);
        assert_eq!(unit.pair.odd.get(1, 0), -4.0);
        assert_eq!(unit.pair.even.get(3, 0), 6.0);
        // lane 1 untouched
        assert_eq!(unit.pair.even.get(1, 1), 0.0);
    }

    #[test]
    fn read_out_is_natural_order_view() {
        let sys = SystemConfig::baseline();
        let m = StridedMapping::new(4, &sys).unwrap();
        let mut unit = UnitState::new(16, 4);
        for w in 0..4 {
            unit.pair.even.set(w, 2, (10 + w) as f32);
        }
        let out = m.read_out(&unit, 2);
        assert_eq!(out.re, vec![10.0, 11.0, 12.0, 13.0]);
    }

    #[test]
    fn rejects_oversize() {
        let sys = SystemConfig::baseline();
        assert!(StridedMapping::new(1 << 19, &sys).is_err());
        assert!(StridedMapping::new(1 << 18, &sys).is_ok());
        // RB×2 doubles the strided limit (§6.6).
        assert!(StridedMapping::new(1 << 19, &SystemConfig::rb2k()).is_ok());
    }

    #[test]
    fn rejects_too_many_ffts() {
        let sys = SystemConfig::baseline();
        let m = StridedMapping::new(4, &sys).unwrap();
        let ffts = vec![SoaVec::zeros(4); 9];
        let mut unit = UnitState::new(16, 4);
        assert!(m.load(&ffts, &mut unit).is_err());
    }

    #[test]
    fn footprint_matches_size() {
        let sys = SystemConfig::baseline();
        let m = StridedMapping::new(256, &sys).unwrap();
        let fp = m.footprint(&sys);
        assert_eq!(fp.words_per_bank, 256);
        assert_eq!(fp.rows_per_bank, 8);
        assert_eq!(fp.ffts_per_unit, 8);
    }
}
