//! The straw "baseline mapping" of paper §4.2.2/Fig 9: one FFT occupies all
//! 8 lanes of `N/8` consecutive words.
//!
//! Kept for the Fig 9 comparison only — the paper (and this crate) uses the
//! strided mapping for everything else. Butterflies with stride < 8 interact
//! across lanes (pim-SHIFT), and per-lane twiddle values require vector
//! loads from a reserved twiddle-table region instead of scalar immediates.

use anyhow::{ensure, Result};

use crate::config::SystemConfig;
use crate::dram::LANES;
use crate::fft::is_pow2;

use super::Footprint;

/// Placement of FFTs across lanes (word-major).
#[derive(Debug, Clone)]
pub struct BaselineMapping {
    n: usize,
}

impl BaselineMapping {
    pub fn new(n: usize, sys: &SystemConfig) -> Result<Self> {
        ensure!(is_pow2(n) && n >= 2, "FFT size must be a power of two >= 2, got {n}");
        ensure!(
            n <= sys.max_bankpair_fft(),
            "FFT size {n} exceeds bank-pair capacity (§4.2.1)"
        );
        Ok(Self { n })
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Words of signal data per FFT.
    pub fn words_per_fft(&self) -> usize {
        self.n.div_ceil(LANES)
    }

    /// (lane, word) of element `elem` of resident FFT `slot`.
    pub fn place(&self, slot: usize, elem: usize) -> (usize, u32) {
        (elem % LANES, (slot * self.words_per_fft() + elem / LANES) as u32)
    }

    /// Words reserved per bank for per-stage twiddle vectors: stages with
    /// butterfly stride ≥ LANES need one (cos, sin) word pair per butterfly
    /// word; lane-crossing stages need them too. One word per stage per
    /// butterfly-word is stored, laid out after the data region.
    pub fn twiddle_words(&self) -> usize {
        // Upper bound: one twiddle word per data word per stage.
        self.words_per_fft() * (self.n.trailing_zeros() as usize)
    }

    pub fn footprint(&self, sys: &SystemConfig) -> Footprint {
        let words = LANES * self.words_per_fft() + self.twiddle_words();
        Footprint {
            words_per_bank: words,
            rows_per_bank: super::rows_for(words, sys),
            ffts_per_unit: LANES,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placement_packs_lanes_first() {
        let sys = SystemConfig::baseline();
        let m = BaselineMapping::new(32, &sys).unwrap();
        assert_eq!(m.words_per_fft(), 4);
        assert_eq!(m.place(0, 0), (0, 0));
        assert_eq!(m.place(0, 7), (7, 0));
        assert_eq!(m.place(0, 8), (0, 1));
        assert_eq!(m.place(2, 9), (1, 9)); // slot 2 starts at word 8
    }

    #[test]
    fn footprint_includes_twiddle_region() {
        let sys = SystemConfig::baseline();
        let m = BaselineMapping::new(64, &sys).unwrap();
        // 8 FFTs × 8 words data + 8×6 twiddle words.
        assert_eq!(m.footprint(&sys).words_per_bank, 64 + 48);
    }

    #[test]
    fn memory_wastage_vs_strided() {
        // The paper's point: baseline wastes memory on twiddle tables that
        // the strided mapping's scalar immediates avoid.
        let sys = SystemConfig::baseline();
        let b = BaselineMapping::new(256, &sys).unwrap();
        let s = crate::mapping::StridedMapping::new(256, &sys).unwrap();
        assert!(b.footprint(&sys).words_per_bank > s.footprint(&sys).words_per_bank);
    }
}
