#!/usr/bin/env python3
"""Cross-PR perf regression gate over BENCH_runtime.json artifacts.

Compares a freshly measured bench report (the candidate) against a baseline
report (the committed BENCH_runtime.json, or a downloaded CI artifact from
the base branch) and fails when throughput regressed beyond the allowed
drop. Schema: docs/BENCHMARKING.md.

What is gated:
  * ``fft`` rows — matched on (kind, log2_n, threads); the metric is
    ``mpoints_per_s`` (higher is better).
  * ``kernels`` rows — matched on (kernel, log2_n); the metric is
    ``mpoints_per_s`` (higher is better). These are the single-thread
    per-transform rows (``radix2-legacy`` vs ``hostkernel``), so a kernel
    regression cannot hide behind batch-level parallelism.
  * ``device`` rows — matched on (backend, log2_n); the metric is
    ``mpoints_per_s`` (higher is better). These compare
    ``ComputeBackend::execute`` on the host reference kernels against the
    stage-dispatch device queue, so the audited device path's overhead is
    gated alongside raw kernel speed.
  * ``cluster`` rows — matched on (shards, threads); the metric is
    ``throughput_rps`` (higher is better).

A baseline with ``"pending": true`` (the pre-measurement stub) or with no
matching rows gates nothing — the gate reports SKIP and exits 0, so the
first measured run can land and become the baseline. Rows present only on
one side are ignored (bench sweeps may grow), but a candidate that lost
*every* baseline row is an error: that is a schema break, not progress.

Usage:
  python3 python/tools/bench_gate.py BASELINE.json CANDIDATE.json \
      [--max-drop-pct 15]

Exit codes: 0 pass/skip, 1 regression, 2 bad input.
"""

from __future__ import annotations

import argparse
import json
import sys


def load(path: str) -> dict:
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench-gate: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)
    if not isinstance(doc, dict):
        print(f"bench-gate: {path} is not a JSON object", file=sys.stderr)
        sys.exit(2)
    return doc


def index_rows(doc: dict, section: str, key_fields: tuple, metric: str) -> dict:
    """Map row-key tuple -> metric value for one report section."""
    out = {}
    for row in doc.get(section, []):
        try:
            key = tuple(row[k] for k in key_fields)
            value = float(row[metric])
        except (KeyError, TypeError, ValueError):
            continue  # malformed row: not comparable, not fatal
        if value > 0:
            out[key] = value
    return out


def compare(
    name: str, base: dict, cand: dict, max_drop_pct: float
) -> tuple[list[str], list[tuple]]:
    """Return (regression messages, delta-table rows)."""
    regressions = []
    rows = []
    for key, base_v in sorted(base.items()):
        cand_v = cand.get(key)
        if cand_v is None:
            continue  # sweep shape changed; only common rows gate
        drop_pct = (base_v - cand_v) / base_v * 100.0
        marker = "REGRESSION" if drop_pct > max_drop_pct else "ok"
        rows.append((name, key, base_v, cand_v, -drop_pct, marker))
        if drop_pct > max_drop_pct:
            regressions.append(
                f"{name} {key}: {base_v:.1f} -> {cand_v:.1f} "
                f"(-{drop_pct:.1f}% > allowed {max_drop_pct:.0f}%)"
            )
    return regressions, rows


def print_delta_table(rows: list[tuple]) -> None:
    """Aligned per-row delta table: every compared row, worst drop first."""
    cells = [
        (
            name,
            " ".join(str(k) for k in key),
            f"{base_v:.1f}",
            f"{cand_v:.1f}",
            f"{delta:+.1f}%",
            marker,
        )
        for name, key, base_v, cand_v, delta, marker in sorted(
            rows, key=lambda r: r[4]
        )
    ]
    header = ("section", "row", "baseline", "candidate", "delta", "")
    widths = [
        max(len(header[i]), *(len(c[i]) for c in cells)) for i in range(len(header))
    ]
    for line in (header, *cells):
        print(
            "  "
            + "  ".join(
                # numbers right-aligned, text left-aligned
                line[i].rjust(widths[i]) if 2 <= i <= 4 else line[i].ljust(widths[i])
                for i in range(len(widths))
            ).rstrip()
        )


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="baseline BENCH_runtime.json")
    ap.add_argument("candidate", help="freshly measured BENCH_runtime.json")
    ap.add_argument(
        "--max-drop-pct",
        type=float,
        default=15.0,
        help="largest tolerated throughput drop, percent (default 15)",
    )
    args = ap.parse_args()

    base = load(args.baseline)
    cand = load(args.candidate)

    if base.get("pending"):
        print(
            "bench-gate: SKIP — baseline is the pre-measurement stub "
            '("pending": true); the candidate becomes the first baseline.'
        )
        return 0
    if cand.get("pending"):
        print("bench-gate: candidate is still a pending stub — nothing was measured", file=sys.stderr)
        return 2

    fft_base = index_rows(base, "fft", ("kind", "log2_n", "threads"), "mpoints_per_s")
    fft_cand = index_rows(cand, "fft", ("kind", "log2_n", "threads"), "mpoints_per_s")
    kr_base = index_rows(base, "kernels", ("kernel", "log2_n"), "mpoints_per_s")
    kr_cand = index_rows(cand, "kernels", ("kernel", "log2_n"), "mpoints_per_s")
    dv_base = index_rows(base, "device", ("backend", "log2_n"), "mpoints_per_s")
    dv_cand = index_rows(cand, "device", ("backend", "log2_n"), "mpoints_per_s")
    cl_base = index_rows(base, "cluster", ("shards", "threads"), "throughput_rps")
    cl_cand = index_rows(cand, "cluster", ("shards", "threads"), "throughput_rps")

    if not fft_base and not kr_base and not dv_base and not cl_base:
        print("bench-gate: SKIP — baseline has no comparable rows")
        return 0

    regressions: list[str] = []
    rows: list[tuple] = []
    for name, b, c in (
        ("fft", fft_base, fft_cand),
        ("kernels", kr_base, kr_cand),
        ("device", dv_base, dv_cand),
        ("cluster", cl_base, cl_cand),
    ):
        r, section_rows = compare(name, b, c, args.max_drop_pct)
        regressions.extend(r)
        rows.extend(section_rows)
    if rows:
        print_delta_table(rows)
    compared = len(rows)

    if compared == 0:
        print(
            "bench-gate: baseline rows exist but the candidate matched none of "
            "them — the bench sweep or schema broke",
            file=sys.stderr,
        )
        return 2
    if regressions:
        print(f"bench-gate: FAIL — {len(regressions)} regression(s):", file=sys.stderr)
        for r in regressions:
            print(f"  {r}", file=sys.stderr)
        return 1
    print(f"bench-gate: PASS — {compared} row(s) within {args.max_drop_pct:.0f}% of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
