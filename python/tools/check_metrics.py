#!/usr/bin/env python3
"""Scrape and validate a live serve-live metrics snapshot over the socket.

Speaks the serving tier's wire protocol (4-byte little-endian length +
UTF-8 JSON frames, see rust/src/serve/protocol.rs), sends a
``{"type": "stats"}`` control frame, and validates the reply:

  * ``digest`` is 16 hex chars and matches ``metrics.digest``;
  * ``prometheus`` is well-formed text exposition 0.0.4 (every line is a
    ``# TYPE`` comment or a ``series value`` sample);
  * ``metrics.counters`` carries the serve counter families and respects
    conservation (served + rejected + dropped + failed <= submitted);
  * with ``--dump``, a ``{"type": "dump"}`` frame also answers and its
    flight-recorder shape is sane.

Intended for CI (scraping a ``serve-live --harness --addr-out`` run
mid-flight) and as the reference out-of-process client for the protocol.

Usage:
  python3 python/tools/check_metrics.py --addr 127.0.0.1:PORT \
      [--addr-file FILE] [--out SNAPSHOT.json] [--retries 50] [--dump]

Exit codes: 0 ok, 1 validation failure, 2 cannot connect / bad input.
"""

from __future__ import annotations

import argparse
import json
import re
import socket
import struct
import sys
import time

MAX_FRAME = 1 << 24
NAME_RE = re.compile(r"[a-zA-Z_:][a-zA-Z0-9_:]*")


def send_frame(sock: socket.socket, msg: dict) -> None:
    body = json.dumps(msg).encode("utf-8")
    if len(body) > MAX_FRAME:
        raise ValueError(f"frame of {len(body)} bytes exceeds the limit")
    sock.sendall(struct.pack("<I", len(body)) + body)


def recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise ConnectionError(f"connection closed mid-frame ({len(buf)}/{n} bytes)")
        buf += chunk
    return buf


def recv_frame(sock: socket.socket) -> dict:
    (length,) = struct.unpack("<I", recv_exact(sock, 4))
    if length > MAX_FRAME:
        raise ValueError(f"frame length {length} exceeds the limit")
    return json.loads(recv_exact(sock, length).decode("utf-8"))


def check_prometheus(text: str) -> list[str]:
    """Return a list of line-format violations (empty = valid)."""
    errors = []
    if not text.strip():
        return ["empty exposition"]
    for line in text.splitlines():
        if not line.strip():
            continue
        if line.startswith("# TYPE "):
            parts = line[len("# TYPE ") :].split()
            if len(parts) != 2 or parts[1] not in ("counter", "gauge", "summary"):
                errors.append(f"bad TYPE line: {line!r}")
            elif not NAME_RE.fullmatch(parts[0]):
                errors.append(f"bad metric name: {line!r}")
            continue
        if line.startswith("#"):
            errors.append(f"unexpected comment: {line!r}")
            continue
        series, _, value = line.rpartition(" ")
        if not series:
            errors.append(f"sample line without a value: {line!r}")
            continue
        name = series.split("{", 1)[0]
        if not NAME_RE.fullmatch(name):
            errors.append(f"bad series name: {line!r}")
        if "{" in series and not series.endswith("}"):
            errors.append(f"unterminated label set: {line!r}")
        if value != "NaN":
            try:
                float(value)
            except ValueError:
                errors.append(f"unparseable value: {line!r}")
    return errors


def validate_stats(reply: dict) -> list[str]:
    errors = []
    if reply.get("type") != "stats":
        return [f"expected a stats reply, got {reply.get('type')!r}: {reply}"]
    digest = reply.get("digest", "")
    if not re.fullmatch(r"[0-9a-f]{16}", digest):
        errors.append(f"digest is not 16 hex chars: {digest!r}")
    metrics = reply.get("metrics", {})
    if metrics.get("digest") != digest:
        errors.append("metrics.digest disagrees with the frame digest")
    errors.extend(check_prometheus(reply.get("prometheus", "")))
    counters = metrics.get("counters", {})
    submitted = counters.get("serve_submitted_total", 0)
    if submitted <= 0:
        errors.append("no submissions observed (serve_submitted_total == 0)")
    served = counters.get("serve_served_total", 0)
    terminal = served + sum(
        v for k, v in counters.items()
        if k.startswith(("serve_rejected_total", "serve_dropped", "serve_failed"))
    )
    if terminal > submitted:
        errors.append(
            f"conservation violated: {terminal} terminal outcomes > {submitted} submitted"
        )
    prom = reply.get("prometheus", "")
    for family in ("serve_submitted_total", "serve_latency_ns"):
        if family not in prom:
            errors.append(f"exposition is missing the {family} family")
    return errors


def validate_dump(reply: dict) -> list[str]:
    if reply.get("type") != "dump":
        return [f"expected a dump reply, got {reply.get('type')!r}: {reply}"]
    flight = reply.get("flight", {})
    errors = []
    for key in ("capacity", "retained", "offered", "evicted", "exemplars"):
        if key not in flight:
            errors.append(f"flight dump is missing {key!r}")
    exemplars = flight.get("exemplars", [])
    if isinstance(exemplars, list) and len(exemplars) != flight.get("retained"):
        errors.append("flight.retained disagrees with len(flight.exemplars)")
    return errors


def connect(addr: str, retries: int) -> socket.socket:
    host, _, port = addr.rpartition(":")
    last: Exception | None = None
    for _ in range(max(1, retries)):
        try:
            return socket.create_connection((host, int(port)), timeout=10.0)
        except OSError as e:
            last = e
            time.sleep(0.1)
    raise ConnectionError(f"cannot connect to {addr}: {last}")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--addr", help="server address, host:port")
    ap.add_argument(
        "--addr-file",
        help="file holding the address (written by serve-live --addr-out); "
        "polled until it appears",
    )
    ap.add_argument("--out", help="write the stats frame's JSON metrics here")
    ap.add_argument("--retries", type=int, default=50, help="connect retries, 100 ms apart")
    ap.add_argument("--dump", action="store_true", help="also fetch + validate a dump frame")
    args = ap.parse_args()

    addr = args.addr
    if not addr and args.addr_file:
        for _ in range(max(1, args.retries)):
            try:
                with open(args.addr_file, encoding="utf-8") as f:
                    addr = f.read().strip()
                if addr:
                    break
            except OSError:
                pass
            time.sleep(0.1)
    if not addr:
        print("check-metrics: need --addr or a readable --addr-file", file=sys.stderr)
        return 2

    try:
        sock = connect(addr, args.retries)
    except (ConnectionError, ValueError) as e:
        print(f"check-metrics: {e}", file=sys.stderr)
        return 2

    with sock:
        # The scraper may connect before the load arrives; poll the stats
        # frame until the tier has seen traffic (or retries run out).
        for attempt in range(max(1, args.retries)):
            send_frame(sock, {"type": "stats"})
            stats = recv_frame(sock)
            counters = stats.get("metrics", {}).get("counters", {})
            if counters.get("serve_submitted_total", 0) > 0:
                break
            if attempt + 1 < args.retries:
                time.sleep(0.1)
        errors = validate_stats(stats)
        if args.dump:
            send_frame(sock, {"type": "dump"})
            errors.extend(validate_dump(recv_frame(sock)))

    if errors:
        print(f"check-metrics: FAIL — {len(errors)} problem(s):", file=sys.stderr)
        for e in errors:
            print(f"  {e}", file=sys.stderr)
        return 1

    counters = stats["metrics"]["counters"]
    print(
        "check-metrics: OK — digest {} | submitted {} served {} | {} prometheus lines".format(
            stats["digest"],
            counters.get("serve_submitted_total", 0),
            counters.get("serve_served_total", 0),
            len(stats["prometheus"].splitlines()),
        )
    )
    if args.out:
        with open(args.out, "w", encoding="utf-8") as f:
            json.dump(stats["metrics"], f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"check-metrics: wrote metrics snapshot to {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
