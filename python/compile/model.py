"""L2: the jax compute graph for the GPU side of Pimacolaba.

Two entry points, both lowered AOT by :mod:`compile.aot` and executed from the
rust coordinator via PJRT -- python is never on the request path:

* :func:`batched_fft` -- the baseline GPU path: a batch of independent
  size-N FFTs, the "single GPU kernel" of paper Fig 11 (N <= LDS/VMEM tile).
* :func:`gpu_component` -- the GPU half of collaborative decomposition
  (paper SS5.1): for each request, view the size-N signal as an (M1, M2)
  matrix (n = n2*M2 + n1), run M2 column FFTs of size M1, and apply the
  inter-factor twiddle W_N^(k2*n1). The rust side then hands each of the M1
  rows (size M2, contiguous -- PIM-friendly) to the PIM-FFT-Tile and gathers
  the final transpose X[k1*M1 + k2] = O[k2, k1].

Both call the L1 Pallas kernel so the butterfly hot-spot lowers into the same
HLO module.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels.fft_kernel import fft_pallas, twiddle_mul_pallas
from .kernels.ref import fourstep_twiddle


def batched_fft(re: jnp.ndarray, im: jnp.ndarray):
    """Forward FFT along the last axis of (B, N) SoA float32 arrays."""
    return tuple(fft_pallas(re, im))


def gpu_component(re: jnp.ndarray, im: jnp.ndarray, m1: int, m2: int):
    """GPU half of the collaborative plan for (B, N=M1*M2) inputs.

    Returns Z as (B, N) flattened row-major over (k2 in [0,M1), n1 in [0,M2)):
    Z[k2, n1] = W_N^(k2*n1) * sum_n2 x[n2*M2 + n1] W_M1^(n2*k2).
    Row n1-contiguity is exactly the layout the PIM strided mapping wants.
    """
    b, n = re.shape
    assert m1 * m2 == n, (m1, m2, n)
    # x[n2, n1]: column FFTs of length M1 = FFT over axis 1 after transpose.
    re3 = re.reshape(b, m1, m2).transpose(0, 2, 1).reshape(b * m2, m1)
    im3 = im.reshape(b, m1, m2).transpose(0, 2, 1).reshape(b * m2, m1)
    yre, yim = fft_pallas(re3, im3)
    # back to [k2, n1]
    yre = yre.reshape(b, m2, m1).transpose(0, 2, 1)
    yim = yim.reshape(b, m2, m1).transpose(0, 2, 1)
    tw_re, tw_im = fourstep_twiddle(n, m1, m2)
    zre, zim = twiddle_mul_pallas(yre, yim, jnp.asarray(tw_re), jnp.asarray(tw_im))
    return zre.reshape(b, n), zim.reshape(b, n)


def gpu_component_cols(re2: jnp.ndarray, im2: jnp.ndarray, m1: int, m2: int):
    """Transpose-free variant of :func:`gpu_component` used for AOT lowering.

    The caller (the rust scheduler) supplies the column gather: input row
    ``sig*M2 + n1`` holds ``x_sig[n2*M2 + n1]`` for ``n2 in [0, M1)``. The
    output keeps the same row layout with ``k2`` along the last axis:
    ``Z2[sig*M2 + n1, k2] = W_N^(k2*n1) * FFT_M1(col n1)[k2]``.

    Why this exists: a jitted transpose lowers to HLO ``transpose`` ops whose
    non-default result layouts inside while-loop tuples mis-execute on the
    xla_extension 0.5.1 CPU runtime the rust `xla` crate embeds (outputs come
    back NaN). Keeping the AOT graph elementwise + Pallas-call only
    sidesteps the bug; the rust side owns the (cheap, host-local) gathers.
    """
    b2, m1_ = re2.shape
    assert m1_ == m1 and b2 % m2 == 0, (re2.shape, m1, m2)
    n = m1 * m2
    yre, yim = fft_pallas(re2, im2)  # FFT over n2 (length M1) per row
    tw_re, tw_im = fourstep_twiddle(n, m1, m2)  # T[k2, n1], shape (m1, m2)
    # Row r has n1 = r % M2: broadcast T^T (m2, m1) over signal groups.
    t2r = jnp.asarray(tw_re.T)[None]  # (1, m2, m1)
    t2i = jnp.asarray(tw_im.T)[None]
    yre3 = yre.reshape(-1, m2, m1)
    yim3 = yim.reshape(-1, m2, m1)
    zre = yre3 * t2r - yim3 * t2i
    zim = yre3 * t2i + yim3 * t2r
    return zre.reshape(b2, m1), zim.reshape(b2, m1)


def fourstep_full(re: jnp.ndarray, im: jnp.ndarray, m1: int, m2: int):
    """Full four-step FFT (GPU component + row FFTs + transpose gather).

    Pure-jax mirror of what coordinator::scheduler does with the PIM
    simulator in the loop; used as a build-time consistency check that the
    decomposition algebra reproduces jnp.fft.fft.
    """
    b, n = re.shape
    zre, zim = gpu_component(re, im, m1, m2)
    zre = zre.reshape(b, m1, m2)
    zim = zim.reshape(b, m1, m2)
    ore, oim = fft_pallas(zre.reshape(b * m1, m2), zim.reshape(b * m1, m2))
    ore = ore.reshape(b, m1, m2).transpose(0, 2, 1).reshape(b, n)  # X[k1*M1+k2]
    oim = oim.reshape(b, m1, m2).transpose(0, 2, 1).reshape(b, n)
    return ore, oim
