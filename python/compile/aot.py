"""AOT lowering: jax (L2+L1) -> HLO *text* artifacts for the rust runtime.

HLO text, NOT ``lowered.compiler_ir().serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published ``xla`` 0.1.6 crate) rejects
(``proto.id() <= INT_MAX``). The text parser reassigns ids and round-trips
cleanly -- see /opt/xla-example/README.md.

Emits one artifact per (kind, N, B[, M1]) variant plus ``manifest.json`` which
the rust ``runtime::artifact`` registry consumes. Run via ``make artifacts``
(no-op when inputs are unchanged); python never runs on the request path.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Baseline GPU-path batched FFT kernels: one artifact per size, canonical
# request-batch 8 (the coordinator's batcher pads partial batches).
FFT_SIZES = [32, 64, 128, 256, 512, 1024, 2048, 4096]
FFT_BATCH = 8

# Collaborative-plan GPU components: (N, M1, M2, B). Tiles M2 are the
# PIM-FFT-Tile sizes the planner may select for the e2e demo sizes.
GPU_PART_VARIANTS = [
    (8192, 256, 32, 4),
    (8192, 128, 64, 4),
    (16384, 512, 32, 4),
    (16384, 256, 64, 4),
    (32768, 1024, 32, 2),
    (65536, 2048, 32, 2),
]


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is load-bearing: the default HLO printer
    # elides big literals as "{...}", which the text parser on the rust side
    # silently zero-fills — bit-reversal permutations and twiddle tables
    # would all become zeros.
    text = comp.as_hlo_text(print_large_constants=True)
    assert "{...}" not in text, "HLO printer elided a constant"
    return text


def lower_fft(n: int, b: int) -> str:
    spec = jax.ShapeDtypeStruct((b, n), jnp.float32)
    return to_hlo_text(jax.jit(model.batched_fft).lower(spec, spec))


def lower_gpu_part(n: int, m1: int, m2: int, b: int) -> str:
    # Column-major contract (see model.gpu_component_cols): rows = b*m2.
    spec = jax.ShapeDtypeStruct((b * m2, m1), jnp.float32)
    fn = lambda re, im: model.gpu_component_cols(re, im, m1, m2)
    return to_hlo_text(jax.jit(fn).lower(spec, spec))


def build(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    entries = []

    def emit(name: str, text: str, **meta):
        path = os.path.join(out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        entries.append(
            dict(
                path=name,
                sha256=hashlib.sha256(text.encode()).hexdigest(),
                **meta,
            )
        )
        print(f"  wrote {name} ({len(text)} chars)")

    for n in FFT_SIZES:
        emit(
            f"fft_n{n}_b{FFT_BATCH}.hlo.txt",
            lower_fft(n, FFT_BATCH),
            kind="fft",
            n=n,
            b=FFT_BATCH,
        )
    for n, m1, m2, b in GPU_PART_VARIANTS:
        emit(
            f"gpupart_n{n}_m1{m1}_b{b}.hlo.txt",
            lower_gpu_part(n, m1, m2, b),
            kind="gpu_part",
            n=n,
            m1=m1,
            m2=m2,
            b=b,
        )

    manifest = {"version": 1, "artifacts": entries}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest: {len(entries)} artifacts")
    return manifest


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts", help="artifact directory")
    args = p.parse_args()
    build(args.out)


if __name__ == "__main__":
    main()
