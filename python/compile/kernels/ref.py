"""Pure-jnp correctness oracles for the FFT kernels.

Two independent references:

* :func:`fft_oracle` -- ``jnp.fft.fft`` on complex64, the ground truth every
  kernel (Pallas L1 and the rust-side reference FFT) is validated against.
* :func:`radix2_dit_soa` -- a straight-line radix-2 decimation-in-time FFT over
  SoA (separate re/im) float32 arrays. This mirrors the butterfly schedule the
  paper maps onto PIM (Figure 1) and is the algorithmic reference for the
  Pallas kernel; it is deliberately written with plain jnp ops only.

All FFTs here are *forward* complex DFTs with the engineering sign convention
``X[k] = sum_n x[n] * exp(-2*pi*i*n*k/N)`` (same as jnp.fft.fft).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def bit_reverse_permutation(n: int) -> np.ndarray:
    """Index permutation that sorts ``n`` points into bit-reversed order.

    ``n`` must be a power of two. Returned as a host numpy array so it can be
    baked into traced programs as a constant gather.
    """
    if n & (n - 1) or n <= 0:
        raise ValueError(f"n must be a positive power of two, got {n}")
    bits = n.bit_length() - 1
    idx = np.arange(n, dtype=np.int32)
    rev = np.zeros(n, dtype=np.int32)
    for b in range(bits):
        rev |= ((idx >> b) & 1) << (bits - 1 - b)
    return rev


def twiddles(m: int) -> tuple:
    """(re, im) of ``W_m^j = exp(-2*pi*i*j/m)`` for ``j in [0, m/2)``."""
    j = np.arange(m // 2, dtype=np.float64)
    ang = -2.0 * np.pi * j / m
    return np.cos(ang).astype(np.float32), np.sin(ang).astype(np.float32)


def radix2_dit_soa(re: jnp.ndarray, im: jnp.ndarray) -> tuple:
    """Batched iterative radix-2 DIT FFT over SoA float32 arrays.

    ``re``/``im`` have shape ``(..., N)`` with ``N`` a power of two; the FFT is
    taken along the last axis. The stage loop is unrolled at trace time (N is
    static), matching the log2(N)-step butterfly schedule of Figure 1.
    """
    n = re.shape[-1]
    perm = bit_reverse_permutation(n)
    re = jnp.take(re, perm, axis=-1)
    im = jnp.take(im, perm, axis=-1)
    stages = n.bit_length() - 1
    lead = re.shape[:-1]
    for s in range(stages):
        half = 1 << s
        m = half * 2
        wr, wi = twiddles(m)  # (half,)
        shape = lead + (n // m, m)
        re = re.reshape(shape)
        im = im.reshape(shape)
        er, od_r = re[..., :half], re[..., half:]
        ei, od_i = im[..., :half], im[..., half:]
        tr = od_r * wr - od_i * wi
        ti = od_r * wi + od_i * wr
        re = jnp.concatenate([er + tr, er - tr], axis=-1)
        im = jnp.concatenate([ei + ti, ei - ti], axis=-1)
    re = re.reshape(lead + (n,))
    im = im.reshape(lead + (n,))
    return re, im


def fft_oracle(re, im) -> tuple:
    """Ground-truth forward FFT via jnp.fft.fft (complex64)."""
    x = jnp.asarray(re, jnp.float32) + 1j * jnp.asarray(im, jnp.float32)
    y = jnp.fft.fft(x.astype(jnp.complex64), axis=-1)
    return jnp.real(y).astype(jnp.float32), jnp.imag(y).astype(jnp.float32)


def fourstep_twiddle(n: int, m1: int, m2: int) -> tuple:
    """Inter-factor twiddle matrix ``T[k2, n1] = W_N^(k2*n1)`` (re, im).

    Used between the GPU component (size-M1 column FFTs) and the PIM component
    (size-M2 row FFTs) of the collaborative decomposition (paper Fig 11).
    Shape ``(m1, m2)``.
    """
    if m1 * m2 != n:
        raise ValueError(f"m1*m2 must equal n: {m1}*{m2} != {n}")
    k2 = np.arange(m1, dtype=np.float64)[:, None]
    n1 = np.arange(m2, dtype=np.float64)[None, :]
    ang = -2.0 * np.pi * (k2 * n1) / n
    return np.cos(ang).astype(np.float32), np.sin(ang).astype(np.float32)
