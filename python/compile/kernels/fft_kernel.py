"""L1 Pallas kernel: batched in-VMEM radix-2 FFT.

Hardware adaptation of the paper's GPU component (see DESIGN.md
SS Hardware-Adaptation): rocFFT keeps one FFT resident in LDS and runs all
log2(N) butterfly stages before writing back; here one (TB, N) tile of the
batch is resident in VMEM, the grid walks the batch dimension, and the whole
stage loop happens on VPU registers/VMEM. HBM traffic is therefore exactly one
read + one write of the signal -- the "single GPU kernel" regime of Fig 11.

The kernel is lowered with ``interpret=True`` everywhere in this repo: the CPU
PJRT plugin cannot execute Mosaic custom-calls, and correctness (vs
``ref.fft_oracle``) is the build-time contract. Real-TPU tiling notes live in
DESIGN.md SSPerf.

Data is SoA float32 (separate re/im), mirroring the paper's even-bank /
odd-bank placement of real and imaginary components (Fig 6).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .ref import bit_reverse_permutation, twiddles

# Soft cap on resident elements per grid step: 2 arrays x TB x N x 4B plus
# twiddle constants must sit comfortably in a ~16 MiB VMEM budget. 1<<16
# elements/array = 512 KiB for both operands -- conservative, leaves room for
# double-buffering on a real TPU.
_VMEM_ELEMS = 1 << 16


def batch_tile(b: int, n: int) -> int:
    """Largest power-of-two batch tile TB such that TB*N fits the VMEM budget
    and TB divides b."""
    tb = max(1, min(b, _VMEM_ELEMS // max(n, 1)))
    while b % tb:
        tb //= 2
    return max(tb, 1)


def packed_twiddles(n: int):
    """All stage twiddles packed into two (N-1,) float32 arrays.

    Stage ``s`` (half = 2**s) occupies the slice ``[2**s - 1, 2**(s+1) - 1)``.
    Packing lets the pallas_call receive every stage constant as a single
    operand pair (pallas kernels may not capture traced constants).
    """
    wr = np.empty(n - 1, np.float32)
    wi = np.empty(n - 1, np.float32)
    for s in range(n.bit_length() - 1):
        half = 1 << s
        r, i = twiddles(half * 2)
        wr[half - 1 : 2 * half - 1] = r
        wi[half - 1 : 2 * half - 1] = i
    return wr, wi


def _fft_stage_loop(re, im, wr_pack, wi_pack, n: int):
    """All log2(N) DIT butterfly stages over a (TB, N) tile held in registers.

    Unrolled at trace time; every stage is a reshape + fused multiply-add, the
    exact butterfly of paper Fig 1 vectorized across the tile.
    """
    tb = re.shape[0]
    stages = n.bit_length() - 1
    for s in range(stages):
        half = 1 << s
        m = half * 2
        wr = wr_pack[half - 1 : 2 * half - 1]
        wi = wi_pack[half - 1 : 2 * half - 1]
        re = re.reshape(tb, n // m, m)
        im = im.reshape(tb, n // m, m)
        er, od_r = re[:, :, :half], re[:, :, half:]
        ei, od_i = im[:, :, :half], im[:, :, half:]
        # Butterfly: t = w * odd; y1 = even + t; y2 = even - t   (Fig 1 right)
        tr = od_r * wr - od_i * wi
        ti = od_r * wi + od_i * wr
        re = jnp.concatenate([er + tr, er - tr], axis=2)
        im = jnp.concatenate([ei + ti, ei - ti], axis=2)
    return re.reshape(tb, n), im.reshape(tb, n)


def _fft_kernel(re_ref, im_ref, perm_ref, wr_ref, wi_ref, out_re_ref, out_im_ref, *, n: int):
    perm = perm_ref[...]
    re = jnp.take(re_ref[...], perm, axis=1)
    im = jnp.take(im_ref[...], perm, axis=1)
    re, im = _fft_stage_loop(re, im, wr_ref[...], wi_ref[...], n)
    out_re_ref[...] = re
    out_im_ref[...] = im


def fft_pallas(re: jnp.ndarray, im: jnp.ndarray, *, interpret: bool = True):
    """Forward FFT along the last axis of a (B, N) SoA pair via Pallas.

    Returns (re, im) of the spectrum. N must be a power of two >= 2.
    """
    b, n = re.shape
    if n & (n - 1) or n < 2:
        raise ValueError(f"N must be a power of two >= 2, got {n}")
    if im.shape != (b, n):
        raise ValueError(f"re/im shape mismatch: {re.shape} vs {im.shape}")
    tb = batch_tile(b, n)
    grid = (b // tb,)
    spec = pl.BlockSpec((tb, n), lambda i: (i, 0))
    perm_spec = pl.BlockSpec((n,), lambda i: (0,))
    tw_spec = pl.BlockSpec((n - 1,), lambda i: (0,)) if n > 1 else perm_spec
    out_shape = [
        jax.ShapeDtypeStruct((b, n), jnp.float32),
        jax.ShapeDtypeStruct((b, n), jnp.float32),
    ]
    perm = jnp.asarray(bit_reverse_permutation(n))
    wr_pack, wi_pack = packed_twiddles(n)
    return pl.pallas_call(
        functools.partial(_fft_kernel, n=n),
        grid=grid,
        in_specs=[spec, spec, perm_spec, tw_spec, tw_spec],
        out_specs=[spec, spec],
        out_shape=out_shape,
        interpret=interpret,
    )(re, im, perm, jnp.asarray(wr_pack), jnp.asarray(wi_pack))


def _twiddle_mul_kernel(re_ref, im_ref, tr_ref, ti_ref, out_re_ref, out_im_ref):
    re, im = re_ref[...], im_ref[...]
    tr, ti = tr_ref[...], ti_ref[...]
    out_re_ref[...] = re * tr - im * ti
    out_im_ref[...] = re * ti + im * tr


def twiddle_mul_pallas(re, im, tw_re, tw_im, *, interpret: bool = True):
    """Elementwise complex multiply of a (B, M1, M2) tile stack by the
    inter-factor twiddle matrix T[k2, n1] (paper Fig 11 GPU->PIM handoff)."""
    b, m1, m2 = re.shape
    tb = batch_tile(b, m1 * m2)
    grid = (b // tb,)
    xspec = pl.BlockSpec((tb, m1, m2), lambda i: (i, 0, 0))
    tspec = pl.BlockSpec((m1, m2), lambda i: (0, 0))
    out_shape = [
        jax.ShapeDtypeStruct((b, m1, m2), jnp.float32),
        jax.ShapeDtypeStruct((b, m1, m2), jnp.float32),
    ]
    return pl.pallas_call(
        _twiddle_mul_kernel,
        grid=grid,
        in_specs=[xspec, xspec, tspec, tspec],
        out_specs=[xspec, xspec],
        out_shape=out_shape,
        interpret=interpret,
    )(re, im, tw_re, tw_im)
