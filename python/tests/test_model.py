"""L2 correctness: the jax model graphs (batched FFT + collaborative
decomposition algebra) against the jnp.fft oracle."""

import numpy as np
import pytest
import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def rand_soa(b, n, seed=0):
    rng = np.random.default_rng(seed)
    return (
        rng.standard_normal((b, n)).astype(np.float32),
        rng.standard_normal((b, n)).astype(np.float32),
    )


class TestBatchedFft:
    @pytest.mark.parametrize("n", [32, 128, 1024])
    def test_matches_oracle(self, n):
        re, im = rand_soa(8, n, seed=n)
        got = model.batched_fft(jnp.asarray(re), jnp.asarray(im))
        want = ref.fft_oracle(re, im)
        np.testing.assert_allclose(np.asarray(got[0]), want[0], atol=1e-2, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(got[1]), want[1], atol=1e-2, rtol=1e-4)


class TestGpuComponent:
    @pytest.mark.parametrize("n,m1,m2", [(64, 8, 8), (256, 32, 8), (1024, 32, 32)])
    def test_manual_composition_matches_oracle(self, n, m1, m2):
        """gpu_component -> numpy row FFTs -> transpose gather == full FFT.

        This is exactly the composition coordinator::scheduler performs with
        the PIM simulator playing the numpy role.
        """
        b = 2
        re, im = rand_soa(b, n, seed=n + m1)
        zre, zim = model.gpu_component(jnp.asarray(re), jnp.asarray(im), m1, m2)
        z = (np.asarray(zre) + 1j * np.asarray(zim)).reshape(b, m1, m2)
        o = np.fft.fft(z, axis=2)  # the PIM tile: M1 row FFTs of size M2
        got = o.transpose(0, 2, 1).reshape(b, n)  # X[k1*M1+k2] = O[k2,k1]
        want = np.fft.fft(np.asarray(re) + 1j * np.asarray(im), axis=1)
        np.testing.assert_allclose(got, want, atol=1e-2, rtol=1e-4)


class TestFourstepFull:
    @pytest.mark.parametrize("n,m1,m2", [(64, 8, 8), (512, 64, 8), (1024, 128, 8)])
    def test_matches_oracle(self, n, m1, m2):
        b = 2
        re, im = rand_soa(b, n, seed=3 * n)
        got_r, got_i = model.fourstep_full(jnp.asarray(re), jnp.asarray(im), m1, m2)
        want_r, want_i = ref.fft_oracle(re, im)
        np.testing.assert_allclose(np.asarray(got_r), want_r, atol=2e-2, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(got_i), want_i, atol=2e-2, rtol=1e-4)


class TestGpuComponentCols:
    @pytest.mark.parametrize("n,m1,m2", [(64, 8, 8), (8192, 256, 32)])
    def test_matches_transpose_variant(self, n, m1, m2):
        b = 2
        re, im = rand_soa(b, n, seed=n + 1)
        # Host-side column gather (what the rust scheduler does).
        re2 = re.reshape(b, m1, m2).transpose(0, 2, 1).reshape(b * m2, m1)
        im2 = im.reshape(b, m1, m2).transpose(0, 2, 1).reshape(b * m2, m1)
        z2r, z2i = model.gpu_component_cols(jnp.asarray(re2), jnp.asarray(im2), m1, m2)
        want_r, want_i = model.gpu_component(jnp.asarray(re), jnp.asarray(im), m1, m2)
        # Z2[sig*m2 + n1, k2] == Z[sig, k2*m2 + n1]
        got_r = np.asarray(z2r).reshape(b, m2, m1).transpose(0, 2, 1).reshape(b, n)
        got_i = np.asarray(z2i).reshape(b, m2, m1).transpose(0, 2, 1).reshape(b, n)
        np.testing.assert_allclose(got_r, np.asarray(want_r), atol=1e-2, rtol=1e-4)
        np.testing.assert_allclose(got_i, np.asarray(want_i), atol=1e-2, rtol=1e-4)
