"""L1 correctness: Pallas FFT kernel vs pure-jnp oracles.

This is the CORE correctness signal for the compute hot-spot: everything the
rust runtime executes was lowered from these functions.
"""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fft_kernel import batch_tile, fft_pallas, twiddle_mul_pallas


def rand_soa(b, n, seed=0):
    rng = np.random.default_rng(seed)
    return (
        rng.standard_normal((b, n)).astype(np.float32),
        rng.standard_normal((b, n)).astype(np.float32),
    )


def assert_fft_close(got, want, n):
    # f32 radix-2 error grows ~ sqrt(log2 N); scale tolerance by signal norm.
    scale = max(np.max(np.abs(want[0])), np.max(np.abs(want[1])), 1.0)
    tol = 2e-6 * scale * (n.bit_length())
    np.testing.assert_allclose(got[0], want[0], atol=tol, rtol=1e-4)
    np.testing.assert_allclose(got[1], want[1], atol=tol, rtol=1e-4)


class TestBitReverse:
    def test_n8(self):
        np.testing.assert_array_equal(
            ref.bit_reverse_permutation(8), [0, 4, 2, 6, 1, 5, 3, 7]
        )

    def test_involution(self):
        for n in [2, 4, 16, 64, 256]:
            p = ref.bit_reverse_permutation(n)
            np.testing.assert_array_equal(p[p], np.arange(n))

    def test_rejects_non_pow2(self):
        for bad in [0, 3, 12, -4]:
            with pytest.raises(ValueError):
                ref.bit_reverse_permutation(bad)


class TestOracleSelfConsistency:
    @pytest.mark.parametrize("n", [2, 4, 8, 32, 128, 1024])
    def test_radix2_matches_jnpfft(self, n):
        re, im = rand_soa(3, n, seed=n)
        got = ref.radix2_dit_soa(jnp.asarray(re), jnp.asarray(im))
        want = ref.fft_oracle(re, im)
        assert_fft_close((np.asarray(got[0]), np.asarray(got[1])), want, n)

    def test_dc_signal(self):
        re = np.ones((1, 16), np.float32)
        im = np.zeros((1, 16), np.float32)
        r, i = ref.radix2_dit_soa(jnp.asarray(re), jnp.asarray(im))
        assert float(r[0, 0]) == pytest.approx(16.0)
        np.testing.assert_allclose(np.asarray(r)[0, 1:], 0, atol=1e-5)
        np.testing.assert_allclose(np.asarray(i), 0, atol=1e-5)


class TestPallasFft:
    @pytest.mark.parametrize("n", [2, 4, 8, 16, 64, 256, 1024])
    @pytest.mark.parametrize("b", [1, 3, 8])
    def test_matches_oracle(self, n, b):
        re, im = rand_soa(b, n, seed=7 * n + b)
        got = fft_pallas(jnp.asarray(re), jnp.asarray(im))
        want = ref.fft_oracle(re, im)
        assert_fft_close((np.asarray(got[0]), np.asarray(got[1])), want, n)

    def test_rejects_non_pow2(self):
        re, im = rand_soa(2, 12)
        with pytest.raises(ValueError):
            fft_pallas(jnp.asarray(re), jnp.asarray(im))

    def test_rejects_shape_mismatch(self):
        with pytest.raises(ValueError):
            fft_pallas(jnp.zeros((2, 8)), jnp.zeros((2, 16)))

    def test_linearity(self):
        re1, im1 = rand_soa(2, 64, seed=1)
        re2, im2 = rand_soa(2, 64, seed=2)
        a = fft_pallas(jnp.asarray(re1 + re2), jnp.asarray(im1 + im2))
        b1 = fft_pallas(jnp.asarray(re1), jnp.asarray(im1))
        b2 = fft_pallas(jnp.asarray(re2), jnp.asarray(im2))
        np.testing.assert_allclose(np.asarray(a[0]), np.asarray(b1[0] + b2[0]), atol=1e-3)
        np.testing.assert_allclose(np.asarray(a[1]), np.asarray(b1[1] + b2[1]), atol=1e-3)

    def test_parseval(self):
        re, im = rand_soa(1, 256, seed=9)
        r, i = fft_pallas(jnp.asarray(re), jnp.asarray(im))
        t = np.sum(re**2 + im**2)
        f = float(jnp.sum(r**2 + i**2)) / 256
        assert f == pytest.approx(t, rel=1e-4)

    @settings(max_examples=25, deadline=None)
    @given(
        logn=st.integers(min_value=1, max_value=9),
        b=st.integers(min_value=1, max_value=6),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_hypothesis_sweep(self, logn, b, seed):
        n = 1 << logn
        rng = np.random.default_rng(seed)
        re = rng.uniform(-4, 4, (b, n)).astype(np.float32)
        im = rng.uniform(-4, 4, (b, n)).astype(np.float32)
        got = fft_pallas(jnp.asarray(re), jnp.asarray(im))
        want = ref.fft_oracle(re, im)
        assert_fft_close((np.asarray(got[0]), np.asarray(got[1])), want, n)


class TestBatchTile:
    def test_divides_batch(self):
        for b in [1, 2, 3, 6, 8, 40]:
            for n in [16, 1024, 65536]:
                tb = batch_tile(b, n)
                assert b % tb == 0 and tb >= 1

    def test_vmem_cap(self):
        assert batch_tile(1024, 65536) == 1
        assert batch_tile(8, 32) == 8


class TestTwiddleMul:
    def test_matches_complex_mul(self):
        b, m1, m2 = 2, 8, 4
        rng = np.random.default_rng(3)
        re = rng.standard_normal((b, m1, m2)).astype(np.float32)
        im = rng.standard_normal((b, m1, m2)).astype(np.float32)
        tr, ti = ref.fourstep_twiddle(m1 * m2, m1, m2)
        got_r, got_i = twiddle_mul_pallas(
            jnp.asarray(re), jnp.asarray(im), jnp.asarray(tr), jnp.asarray(ti)
        )
        x = re + 1j * im
        t = tr + 1j * ti
        want = x * t[None]
        np.testing.assert_allclose(np.asarray(got_r), want.real, atol=1e-5)
        np.testing.assert_allclose(np.asarray(got_i), want.imag, atol=1e-5)


class TestFourstepTwiddle:
    def test_unit_modulus(self):
        tr, ti = ref.fourstep_twiddle(64, 8, 8)
        np.testing.assert_allclose(tr**2 + ti**2, 1.0, atol=1e-6)

    def test_first_row_col_is_one(self):
        tr, ti = ref.fourstep_twiddle(64, 16, 4)
        np.testing.assert_allclose(tr[0], 1.0, atol=1e-7)
        np.testing.assert_allclose(tr[:, 0], 1.0, atol=1e-7)
        np.testing.assert_allclose(ti[0], 0.0, atol=1e-7)

    def test_rejects_bad_factorization(self):
        with pytest.raises(ValueError):
            ref.fourstep_twiddle(64, 8, 4)
